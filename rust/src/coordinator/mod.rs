//! The Dynamic GUS coordinator — the serving core (§3).
//!
//! Owns the three components of the paper's architecture and wires them
//! into the two RPC families:
//!
//! - **Mutation RPCs** (§3.3.1–3.3.2): insert/update computes the sparse
//!   embedding with the Embedding Generator and upserts `(p, M(p))` into
//!   the ANN index (plus the feature store, which the scorer needs to score
//!   retrieved candidates); delete removes the point. Both return an
//!   acknowledgment.
//! - **Neighborhood RPC** (§3.3.3): embed the (new or known) query point,
//!   retrieve the ScaNN-NN closest points `Q` from the index, score `p`
//!   against each `q ∈ Q` with the model, and return `(Q, S)`.
//!
//! Everything on the request path is local in-memory state: the bucketer,
//! the IDF/filter tables, the posting lists, the feature store, and the
//! (pre-compiled) scorer. Freshness is immediate: a mutation is visible to
//! the next query the moment its ack returns ([`staleness`] tracks the
//! mutation-to-visibility interval the paper bounds by "a few seconds" at
//! the 99th percentile; here it is the mutation latency itself).
//!
//! # Threading and batch RPCs
//!
//! The index is a [`ShardedIndex`] served by up to
//! [`GusConfig::resolved_query_threads`] workers: single queries fan out
//! across shards in parallel, and the batch RPCs parallelize across items
//! (embedding, retrieval and scoring all run on the scoped worker pool,
//! drawing reusable query scratches from the index's pool — the hot path
//! is allocation-free). Scoring runs the packed tile kernel
//! ([`crate::scorer`]): candidate features are fetched with one
//! [`FeatureStore::get_many`], every buffer is pooled per worker, and a
//! single query's large candidate list splits across the same workers
//! ([`score_into_parallel`]). Thread count never changes results.
//!
//! - [`DynamicGus::insert_batch`] embeds points in parallel and groups
//!   index upserts by shard so each shard's write lock is taken once per
//!   batch. The whole batch is schema-validated up front: on error the
//!   service state is untouched. The batch's wall time is recorded once in
//!   `mutation_latency` (it is also the batch's staleness bound);
//!   per-point counters are still exact. [`DynamicGus::delete_batch`] is
//!   the mirror-image bulk delete.
//! - [`DynamicGus::query_batch`] answers each query identically to
//!   [`DynamicGus::query`] (same retrieval, same scoring, same order) —
//!   entry `i` equals `query(&points[i], k)` run against the same
//!   snapshot. The batch wall time is recorded once in `query_latency`;
//!   the `queries` counter advances by the batch length.
//!
//! # Durability
//!
//! With `wal_dir` configured, every accepted mutation is appended to a
//! write-ahead log *before* it is applied ([`wal`]), and
//! [`DynamicGus::checkpoint`] folds the log into an incremental snapshot
//! ([`snapshot`]). [`wal::recover`] restores latest-checkpoint + WAL-tail
//! after a crash; the [`wal::Checkpointer`] bounds the tail length in the
//! background. See `docs/ARCHITECTURE.md` for the full picture.

pub mod ingest;
pub mod snapshot;
pub mod staleness;
pub mod store;
pub mod wal;

use std::sync::{Arc, MutexGuard, OnceLock, RwLock};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{GusConfig, ScorerKind};
use crate::embed::EmbeddingGenerator;
use crate::features::{Point, PointId, Schema};
use crate::index::sharded::ShardedIndex;
use crate::index::QueryParams;
use crate::lsh::Bucketer;
use crate::metrics::{Counters, LatencyHistogram, ReplicationGauges};
use crate::preprocess;
use crate::scorer::{
    score_into_parallel, CandRefs, MlpWeights, NativeScorer, PairFeaturizer, PairScorer,
    ScratchPool, XlaScorer, HIDDEN,
};
use crate::util::json::Json;

pub use ingest::{IngestPipeline, Mutation};
pub use staleness::StalenessTracker;
pub use store::FeatureStore;

/// A scored neighbor returned by the Neighborhood RPC: the model similarity
/// plus the embedding-space dot (diagnostics / ablations).
#[derive(Debug, Clone, PartialEq)]
pub struct ScoredNeighbor {
    pub id: PointId,
    pub score: f32,
    pub dot: f32,
}

/// How far to degrade one query under overload: scale the posting-scan
/// budget, and at the last tier skip the scoring refinement entirely.
/// Produced by the admission controller ([`crate::admission`]), applied
/// by the `*_degraded` query methods; the server marks the response
/// `degraded` so clients can tell a cheap answer from a full one.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DegradeSpec {
    /// Fraction of the full `max_postings` budget to spend, in (0, 1].
    pub budget_frac: f64,
    /// Skip model scoring: rank retrieved candidates by embedding dot
    /// (`score == dot`). The cheapest answer that is still a neighborhood.
    pub skip_refine: bool,
}

/// Service metrics bundle.
#[derive(Default)]
pub struct GusMetrics {
    pub mutation_latency: LatencyHistogram,
    pub query_latency: LatencyHistogram,
    /// The scoring phase of each Neighborhood RPC (feature fetch + pair
    /// scoring + result sort) — subtract from `query_latency` to get
    /// retrieval time; the pure `score_into` span accumulates in
    /// `counters.pairs_scored_ns`.
    pub scoring_latency: LatencyHistogram,
    pub counters: Counters,
    pub staleness: StalenessTracker,
    /// Replication health (role, stream lag, apply staleness). Zeroed
    /// with role `single` when replication is off.
    pub replication: ReplicationGauges,
}

/// Reusable buffers for one `score_neighbors` call: candidate ids, fetched
/// features, the surviving `(neighbor, features)` pairs, the borrowed
/// candidate-ref list and the score output. Pooled per worker
/// ([`crate::util::pool::Pool`]) so the Neighborhood RPC's scoring phase
/// allocates nothing in steady state beyond the returned
/// `Vec<ScoredNeighbor>` and `get_many`'s small per-call shard-guard
/// table. `Arc<Point>` payloads are cleared
/// before a scratch returns to the pool, so an idle pool never pins
/// feature data of (possibly deleted) candidates.
#[derive(Default)]
struct NeighborScratch {
    ids: Vec<PointId>,
    arcs: Vec<Option<Arc<Point>>>,
    kept: Vec<(crate::index::Neighbor, Arc<Point>)>,
    refs: CandRefs,
    scores: Vec<f32>,
}

/// The Dynamic GUS service.
pub struct DynamicGus {
    schema: Schema,
    config: GusConfig,
    embedder: RwLock<EmbeddingGenerator>,
    index: ShardedIndex,
    store: FeatureStore,
    scorer: Box<dyn PairScorer>,
    /// Per-worker scorer scratches (φ tiles, extras staging, query prep).
    scorer_scratch: ScratchPool,
    /// Per-worker `score_neighbors` buffers.
    neighbor_scratch: crate::util::pool::Pool<NeighborScratch>,
    /// Durability state; absent until [`DynamicGus::attach_wal`] (see
    /// [`wal::init_fresh`] / [`wal::recover`]). Attached at most once.
    wal: OnceLock<wal::WalHandle>,
    pub metrics: GusMetrics,
}

impl DynamicGus {
    /// Boot the service: offline preprocessing over the initial corpus
    /// (§4.3), index warm-up, scorer selection.
    pub fn bootstrap(
        schema: Schema,
        config: GusConfig,
        initial: &[Point],
        threads: usize,
    ) -> Result<DynamicGus> {
        config.validate().map_err(|e| anyhow!(e))?;
        let scorer = Self::make_scorer(&schema, config.scorer)?;
        Self::bootstrap_with_scorer(schema, config, initial, threads, scorer)
    }

    /// Boot with an explicit scorer (tests, custom models).
    pub fn bootstrap_with_scorer(
        schema: Schema,
        config: GusConfig,
        initial: &[Point],
        threads: usize,
        scorer: Box<dyn PairScorer>,
    ) -> Result<DynamicGus> {
        let bucketer = Bucketer::with_defaults(&schema, config.lsh_seed);
        let pre = preprocess::preprocess(&bucketer, initial, &config, threads);
        let embedder = preprocess::build_generator(bucketer, &pre);

        let gus = DynamicGus {
            schema,
            config: config.clone(),
            embedder: RwLock::new(embedder),
            index: ShardedIndex::with_threads(config.n_shards, config.resolved_query_threads()),
            store: FeatureStore::new(config.n_shards.max(4)),
            scorer,
            scorer_scratch: ScratchPool::new(),
            neighbor_scratch: crate::util::pool::Pool::new(),
            wal: OnceLock::new(),
            metrics: GusMetrics::default(),
        };
        for p in initial {
            gus.apply_insert(p.clone())?;
        }
        // Bootstrapping inserts are not request-path mutations: reset.
        gus.metrics.mutation_latency.reset();
        gus.metrics
            .counters
            .inserts
            .store(0, std::sync::atomic::Ordering::Relaxed);
        Ok(gus)
    }

    /// Choose the scorer backend (Auto prefers XLA artifacts).
    pub fn make_scorer(schema: &Schema, kind: ScorerKind) -> Result<Box<dyn PairScorer>> {
        let featurizer = PairFeaturizer::new(schema);
        let dir = crate::runtime::artifacts_dir();
        let use_xla = match kind {
            ScorerKind::Xla => true,
            ScorerKind::Native => false,
            ScorerKind::Auto => XlaScorer::artifacts_available(&dir, &schema.name),
        };
        if use_xla {
            Ok(Box::new(XlaScorer::load(featurizer, &dir)?))
        } else {
            let weights_path = XlaScorer::weights_path(&dir, &schema.name);
            let weights = if weights_path.exists() {
                MlpWeights::load(&weights_path)?
            } else {
                // No trained artifact: deterministic random weights keep the
                // pipeline runnable (quality figures then use `native`
                // trained weights from `make artifacts`).
                MlpWeights::random(featurizer.input_dim(), HIDDEN, 0x5eed)
            };
            Ok(Box::new(NativeScorer::new(featurizer, weights)))
        }
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn config(&self) -> &GusConfig {
        &self.config
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn contains(&self, id: PointId) -> bool {
        self.store.get(id).is_some()
    }

    // ---------- durability ----------

    /// Attach write-ahead logging. At most once per service; normally
    /// called through [`wal::init_fresh`] or [`wal::recover`], which also
    /// manage the on-disk state.
    pub fn attach_wal(&self, handle: wal::WalHandle) -> Result<()> {
        self.wal
            .set(handle)
            .map_err(|_| anyhow!("WAL already attached"))
    }

    /// The attached durability state, if any.
    pub fn wal(&self) -> Option<&wal::WalHandle> {
        self.wal.get()
    }

    /// Mutations logged since the last checkpoint (0 when no WAL).
    pub fn wal_pending(&self) -> u64 {
        self.wal.get().map(|w| w.pending()).unwrap_or(0)
    }

    /// Sequence number of the most recently logged mutation (0 when no
    /// WAL). Takes the WAL lock briefly.
    pub fn wal_seq(&self) -> u64 {
        self.wal.get().map(|w| w.seq()).unwrap_or(0)
    }

    /// Log one mutation record before applying it. Returns a guard that
    /// the caller must hold until the mutation is **applied**: holding the
    /// WAL lock across log + apply is what makes a checkpoint's
    /// `(store, last_seq)` pair consistent (see [`wal`] module docs).
    /// `None` (no guard, nothing logged) when durability is off.
    fn wal_log(
        &self,
        payload: impl FnOnce() -> crate::util::json::Json,
        n_mutations: u64,
    ) -> Result<Option<MutexGuard<'_, wal::WalWriter>>> {
        match self.wal.get() {
            None => Ok(None),
            Some(w) => {
                let mut writer = w.writer.lock().unwrap();
                writer.append(&payload())?;
                w.add_pending(n_mutations);
                Ok(Some(writer))
            }
        }
    }

    /// Incremental checkpoint: persist the corpus + tables (committed by
    /// an atomic rename), then truncate the WAL — keeping the last
    /// [`GusConfig::wal_retain`] records as a bounded tail so replication
    /// followers lagging by less than that can keep streaming instead of
    /// re-bootstrapping from the snapshot. Blocks mutations for the
    /// duration (they queue on the WAL lock); returns the sequence number
    /// the checkpoint covers. Errors if no WAL is attached.
    pub fn checkpoint(&self) -> Result<u64> {
        let w = self
            .wal
            .get()
            .ok_or_else(|| anyhow!("no WAL attached (serve with --wal-dir)"))?;
        let mut writer = w.writer.lock().unwrap();
        let seq = writer.seq();
        // Pass the writer's captured injector so `checkpoint_rename`
        // fault rules fire against the same plan as the WAL sites.
        snapshot::save_with_seq_injected(self, w.dir(), seq, writer.fault_injector().as_deref())?;
        writer.truncate_retaining(self.config.wal_retain)?;
        w.reset_pending();
        Ok(seq)
    }

    /// Apply one WAL record during recovery (no logging, no metrics —
    /// replayed mutations were already counted by their first life).
    /// Returns the number of mutations the record carried, weighted like
    /// live logging (a batch record counts its items), so recovery can
    /// seed the pending-checkpoint counter consistently. Callers
    /// guarantee the WAL is not yet attached.
    ///
    /// Payloads decode through the typed protocol module — the same
    /// [`crate::protocol::Request::from_wire`] path the server speaks —
    /// so the wire format and the log format cannot drift apart.
    pub(crate) fn apply_logged(
        &self,
        payload: &crate::util::json::Json,
        threads: usize,
    ) -> Result<u64> {
        use crate::protocol::Request;
        let req = Request::from_wire(payload).map_err(|e| anyhow!("WAL record: {e}"))?;
        match req {
            Request::Insert { point } => {
                self.apply_insert(point)?;
                Ok(1)
            }
            Request::Delete { id } => {
                self.apply_delete(id);
                Ok(1)
            }
            Request::InsertBatch { points } => {
                let n = points.len() as u64;
                for p in points {
                    self.apply_insert(p)?;
                }
                Ok(n)
            }
            Request::DeleteBatch { ids } => {
                for &id in &ids {
                    self.apply_delete(id);
                }
                Ok(ids.len() as u64)
            }
            Request::RefreshTables => {
                self.refresh_tables(threads)?;
                Ok(1)
            }
            other => anyhow::bail!("non-mutation op '{}' in WAL", other.op_name()),
        }
    }

    // ---------- mutation RPCs ----------

    fn apply_insert(&self, p: Point) -> Result<bool> {
        self.schema.validate(&p).map_err(|e| anyhow!("{e}"))?;
        Ok(self.apply_insert_unchecked(p))
    }

    /// Embed + store + index a point the caller has already validated
    /// (the request path validates before WAL logging, so re-validating
    /// here would double the per-mutation schema work).
    fn apply_insert_unchecked(&self, p: Point) -> bool {
        let embedding = { self.embedder.read().unwrap().embed(&p) };
        let id = p.id;
        self.store.put(p);
        self.index.upsert(id, embedding)
    }

    fn apply_delete(&self, id: PointId) -> bool {
        let in_index = self.index.remove(id);
        let in_store = self.store.remove(id).is_some();
        debug_assert_eq!(in_index, in_store);
        in_index
    }

    /// Mutation RPC: insert or update (§3.3.1). Returns `true` if the point
    /// already existed (update). With durability on, the mutation is
    /// logged before it is applied: once this returns, a crash cannot
    /// lose it.
    pub fn insert(&self, p: Point) -> Result<bool> {
        let t0 = Instant::now();
        self.schema.validate(&p).map_err(|e| anyhow!("{e}"))?;
        let _wal = self.wal_log(|| wal::insert_payload(&p), 1)?;
        let existed = self.apply_insert_unchecked(p);
        let dt = t0.elapsed();
        self.metrics.mutation_latency.record(dt);
        self.metrics.staleness.record_visible(dt);
        use std::sync::atomic::Ordering::Relaxed;
        if existed {
            self.metrics.counters.updates.fetch_add(1, Relaxed);
        } else {
            self.metrics.counters.inserts.fetch_add(1, Relaxed);
        }
        Ok(existed)
    }

    /// Mutation RPC: delete (§3.3.2). Returns `true` if present.
    /// Log-before-apply, like [`insert`](DynamicGus::insert).
    pub fn delete(&self, id: PointId) -> Result<bool> {
        let t0 = Instant::now();
        let _wal = self.wal_log(|| wal::delete_payload(id), 1)?;
        let in_index = self.apply_delete(id);
        let dt = t0.elapsed();
        self.metrics.mutation_latency.record(dt);
        self.metrics.staleness.record_visible(dt);
        self.metrics
            .counters
            .deletes
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(in_index)
    }

    /// Query-time retrieval params for a point.
    fn query_params(&self, p: &Point) -> QueryParams {
        QueryParams {
            exclude: Some(p.id),
            max_postings: self.config.max_postings,
        }
    }

    /// Score retrieved candidates against the query point and sort by
    /// model score desc (id asc on ties; `total_cmp`, so a NaN score — a
    /// pathological weight vector can produce one through inf−inf — sorts
    /// deterministically instead of panicking). Neighbors whose features
    /// are gone by scoring time (concurrently deleted) are dropped — they
    /// are filtered *before* scoring so every neighbor is paired with its
    /// own score (zipping raw neighbors against the filtered candidates
    /// used to misalign the pairs whenever a delete raced a query).
    ///
    /// Allocation-free in steady state: candidate features come from one
    /// [`FeatureStore::get_many`] (each store shard locked once), all
    /// intermediate buffers are pooled per worker, and with `par_threads >
    /// 1` a large candidate list is split across the scoped worker pool
    /// ([`score_into_parallel`]) — a single query's scoring parallelizes
    /// the way `query_batch` parallelizes across queries. The batch path
    /// passes `par_threads = 1` (it is already one-query-per-worker; nested
    /// fan-out would oversubscribe the pool).
    fn score_neighbors(
        &self,
        p: &Point,
        neighbors: &[crate::index::Neighbor],
        par_threads: usize,
    ) -> Vec<ScoredNeighbor> {
        use std::sync::atomic::Ordering::Relaxed;
        let counters = &self.metrics.counters;
        counters
            .candidates_retrieved
            .fetch_add(neighbors.len() as u64, Relaxed);
        if neighbors.is_empty() {
            return Vec::new();
        }
        let t_phase = Instant::now();
        let mut s = self.neighbor_scratch.take();
        s.ids.clear();
        s.ids.extend(neighbors.iter().map(|n| n.id));
        self.store.get_many(&s.ids, &mut s.arcs);
        s.kept.clear();
        for (n, arc) in neighbors.iter().zip(s.arcs.drain(..)) {
            if let Some(a) = arc {
                s.kept.push((*n, a));
            }
        }
        let mut refs = s.refs.take();
        refs.extend(s.kept.iter().map(|(_, a)| a.as_ref()));
        s.scores.clear();
        let t_score = Instant::now();
        score_into_parallel(
            &*self.scorer,
            p,
            &refs,
            &self.scorer_scratch,
            par_threads,
            &mut s.scores,
        );
        counters
            .pairs_scored_ns
            .fetch_add(t_score.elapsed().as_nanos() as u64, Relaxed);
        counters.pairs_scored.fetch_add(s.scores.len() as u64, Relaxed);
        debug_assert_eq!(s.scores.len(), s.kept.len());
        let mut out: Vec<ScoredNeighbor> = Vec::with_capacity(neighbors.len());
        out.extend(
            s.kept
                .iter()
                .zip(&s.scores)
                .map(|((n, _), &score)| ScoredNeighbor { id: n.id, score, dot: n.dot }),
        );
        out.sort_unstable_by(|a, b| b.score.total_cmp(&a.score).then(a.id.cmp(&b.id)));
        s.refs.put(refs);
        // Drop the Arc<Point> payloads before pooling: a scratch parked in
        // the pool must not keep candidate features (possibly deleted by
        // now) alive. Capacity is what we recycle, not contents.
        s.kept.clear();
        s.scores.clear();
        self.neighbor_scratch.put(s);
        self.metrics.scoring_latency.record(t_phase.elapsed());
        out
    }

    /// Neighborhood RPC (§3.3.3) for a point given by features (may be new
    /// or existing). Returns scored neighbors sorted by model score desc.
    pub fn query(&self, p: &Point, k: usize) -> Result<Vec<ScoredNeighbor>> {
        let t0 = Instant::now();
        self.schema.validate(p).map_err(|e| anyhow!("{e}"))?;
        let embedding = { self.embedder.read().unwrap().embed(p) };
        let neighbors = self.index.top_k(&embedding, k, self.query_params(p));
        let out = self.score_neighbors(p, &neighbors, self.index.query_threads());
        self.metrics.query_latency.record(t0.elapsed());
        self.metrics
            .counters
            .queries
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }

    /// Batch Neighborhood RPC: answer `k`-neighborhoods for many points in
    /// one call. Embedding, retrieval and scoring run in parallel across
    /// queries on the serving workers; entry `i` is exactly what
    /// [`query`](DynamicGus::query) would return for `points[i]` against
    /// the same index snapshot.
    pub fn query_batch(&self, points: &[Point], k: usize) -> Result<Vec<Vec<ScoredNeighbor>>> {
        if points.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        for p in points {
            self.schema.validate(p).map_err(|e| anyhow!("{e}"))?;
        }
        // Same worker count the index resolved at construction.
        let threads = self.index.query_threads();
        let queries: Vec<(crate::sparse::SparseVec, QueryParams)> = {
            let guard = self.embedder.read().unwrap();
            let em = &*guard;
            crate::util::threadpool::parallel_map(points.len(), threads, |i| {
                (em.embed(&points[i]), self.query_params(&points[i]))
            })
        };
        let neighbor_lists = self.index.query_batch(&queries, k);
        let out = crate::util::threadpool::parallel_map(points.len(), threads, |i| {
            // One query per worker: no nested scoring fan-out.
            self.score_neighbors(&points[i], &neighbor_lists[i], 1)
        });
        self.metrics.query_latency.record(t0.elapsed());
        self.metrics
            .counters
            .queries
            .fetch_add(points.len() as u64, std::sync::atomic::Ordering::Relaxed);
        Ok(out)
    }

    /// Batch Mutation RPC: insert or update many points in one call.
    /// The whole batch is schema-validated first (on error nothing is
    /// applied), embeddings are computed in parallel, and index upserts
    /// are grouped by shard (one write-lock acquisition per shard).
    /// Returns, per input position, whether the point already existed.
    /// Duplicate ids within a batch apply in input order.
    pub fn insert_batch(&self, points: Vec<Point>) -> Result<Vec<bool>> {
        if points.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        for p in &points {
            self.schema.validate(p).map_err(|e| anyhow!("{e}"))?;
        }
        let threads = self.index.query_threads();
        // One WAL record for the whole (validated) batch — logged *before*
        // embedding so the batch's position in the mutation order matches
        // the tables it embeds under (a concurrent `refresh_tables` also
        // serializes on the WAL lock). Embedding still parallelizes across
        // items inside the lock.
        let _wal = self.wal_log(|| wal::insert_batch_payload(&points), points.len() as u64)?;
        let items: Vec<(PointId, crate::sparse::SparseVec)> = {
            let guard = self.embedder.read().unwrap();
            let em = &*guard;
            crate::util::threadpool::parallel_map(points.len(), threads, |i| {
                (points[i].id, em.embed(&points[i]))
            })
        };
        // Store before indexing, matching the single-insert order (a
        // racing query sees features for everything the index returns).
        for p in points {
            self.store.put(p);
        }
        let existed = self.index.upsert_batch(items);
        let dt = t0.elapsed();
        self.metrics.mutation_latency.record(dt);
        self.metrics.staleness.record_visible(dt);
        use std::sync::atomic::Ordering::Relaxed;
        let updates = existed.iter().filter(|&&e| e).count() as u64;
        self.metrics.counters.updates.fetch_add(updates, Relaxed);
        self.metrics
            .counters
            .inserts
            .fetch_add(existed.len() as u64 - updates, Relaxed);
        Ok(existed)
    }

    /// Batch Mutation RPC: delete many points in one call. Index removals
    /// are grouped by shard (one write-lock acquisition per shard, via
    /// [`ShardedIndex::remove_batch`]). Returns, per input position,
    /// whether the point was present.
    pub fn delete_batch(&self, ids: &[PointId]) -> Result<Vec<bool>> {
        if ids.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        let _wal = self.wal_log(|| wal::delete_batch_payload(ids), ids.len() as u64)?;
        // Index first, then store — the same order as the single delete
        // (a racing query never sees an indexed point without features).
        let existed = self.index.remove_batch(ids);
        for &id in ids {
            self.store.remove(id);
        }
        let dt = t0.elapsed();
        self.metrics.mutation_latency.record(dt);
        self.metrics.staleness.record_visible(dt);
        self.metrics
            .counters
            .deletes
            .fetch_add(ids.len() as u64, std::sync::atomic::Ordering::Relaxed);
        Ok(existed)
    }

    /// Neighborhood RPC for an existing point by id.
    pub fn query_by_id(&self, id: PointId, k: usize) -> Result<Vec<ScoredNeighbor>> {
        let p = self
            .store
            .get(id)
            .ok_or_else(|| anyhow!("unknown point {id}"))?;
        self.query(&p, k)
    }

    // ---------- degraded serving (overload) ----------

    /// The scan budget a degraded query runs under. With a configured
    /// budget it is simply scaled; with `max_postings = 0` (exact scan)
    /// the budget is derived from the current live posting count so the
    /// fraction still binds. Never zero — zero means "exact" to the index.
    fn degraded_budget(&self, frac: f64) -> usize {
        let base = if self.config.max_postings > 0 {
            self.config.max_postings
        } else {
            self.index.stats().live_postings
        };
        ((base as f64 * frac).ceil() as usize).max(1)
    }

    /// Rank retrieved candidates by their embedding dot, skipping the
    /// scoring model (`score == dot`). Same tie-break as the scored path.
    fn rank_by_dot(neighbors: &[crate::index::Neighbor]) -> Vec<ScoredNeighbor> {
        let mut out: Vec<ScoredNeighbor> = neighbors
            .iter()
            .map(|n| ScoredNeighbor { id: n.id, score: n.dot, dot: n.dot })
            .collect();
        out.sort_unstable_by(|a, b| b.score.total_cmp(&a.score).then(a.id.cmp(&b.id)));
        out
    }

    /// [`query`](DynamicGus::query) under a [`DegradeSpec`]: the retrieval
    /// scan budget is scaled by `budget_frac`, and with `skip_refine` the
    /// candidates come back dot-ranked instead of model-scored. A spec of
    /// `{1.0, false}` answers exactly like `query` (modulo the derived
    /// budget when `max_postings = 0`).
    pub fn query_degraded(&self, p: &Point, k: usize, spec: DegradeSpec) -> Result<Vec<ScoredNeighbor>> {
        use std::sync::atomic::Ordering::Relaxed;
        let t0 = Instant::now();
        self.schema.validate(p).map_err(|e| anyhow!("{e}"))?;
        let embedding = { self.embedder.read().unwrap().embed(p) };
        let params = QueryParams {
            exclude: Some(p.id),
            max_postings: self.degraded_budget(spec.budget_frac),
        };
        let neighbors = self.index.top_k(&embedding, k, params);
        let out = if spec.skip_refine {
            self.metrics
                .counters
                .candidates_retrieved
                .fetch_add(neighbors.len() as u64, Relaxed);
            Self::rank_by_dot(&neighbors)
        } else {
            self.score_neighbors(p, &neighbors, self.index.query_threads())
        };
        self.metrics.query_latency.record(t0.elapsed());
        self.metrics.counters.queries.fetch_add(1, Relaxed);
        Ok(out)
    }

    /// [`query_by_id`](DynamicGus::query_by_id) under a [`DegradeSpec`].
    pub fn query_by_id_degraded(
        &self,
        id: PointId,
        k: usize,
        spec: DegradeSpec,
    ) -> Result<Vec<ScoredNeighbor>> {
        let p = self
            .store
            .get(id)
            .ok_or_else(|| anyhow!("unknown point {id}"))?;
        self.query_degraded(&p, k, spec)
    }

    /// [`query_batch`](DynamicGus::query_batch) under a [`DegradeSpec`]:
    /// entry `i` equals `query_degraded(&points[i], k, spec)` against the
    /// same snapshot. The budget is derived once for the whole batch.
    pub fn query_batch_degraded(
        &self,
        points: &[Point],
        k: usize,
        spec: DegradeSpec,
    ) -> Result<Vec<Vec<ScoredNeighbor>>> {
        use std::sync::atomic::Ordering::Relaxed;
        if points.is_empty() {
            return Ok(Vec::new());
        }
        let t0 = Instant::now();
        for p in points {
            self.schema.validate(p).map_err(|e| anyhow!("{e}"))?;
        }
        let threads = self.index.query_threads();
        let budget = self.degraded_budget(spec.budget_frac);
        let queries: Vec<(crate::sparse::SparseVec, QueryParams)> = {
            let guard = self.embedder.read().unwrap();
            let em = &*guard;
            crate::util::threadpool::parallel_map(points.len(), threads, |i| {
                (
                    em.embed(&points[i]),
                    QueryParams { exclude: Some(points[i].id), max_postings: budget },
                )
            })
        };
        let neighbor_lists = self.index.query_batch(&queries, k);
        let out = if spec.skip_refine {
            let total: usize = neighbor_lists.iter().map(Vec::len).sum();
            self.metrics.counters.candidates_retrieved.fetch_add(total as u64, Relaxed);
            neighbor_lists.iter().map(|ns| Self::rank_by_dot(ns)).collect()
        } else {
            crate::util::threadpool::parallel_map(points.len(), threads, |i| {
                // One query per worker: no nested scoring fan-out.
                self.score_neighbors(&points[i], &neighbor_lists[i], 1)
            })
        };
        self.metrics.query_latency.record(t0.elapsed());
        self.metrics.counters.queries.fetch_add(points.len() as u64, Relaxed);
        Ok(out)
    }

    /// Periodic reload (§4.3): recompute IDF/filter tables from the current
    /// corpus and swap them in without downtime. Re-embeds and re-indexes
    /// all points (embeddings depend on the tables). Logged to the WAL:
    /// table derivation is deterministic in the corpus, so replay
    /// reproduces the same tables at the same position in the mutation
    /// stream.
    pub fn refresh_tables(&self, threads: usize) -> Result<()> {
        let _wal = self.wal_log(wal::refresh_payload, 1)?;
        let snapshot = self.store.snapshot();
        let points: Vec<Point> = snapshot.iter().map(|a| (**a).clone()).collect();
        let bucketer = Bucketer::with_defaults(&self.schema, self.config.lsh_seed);
        let pre = preprocess::preprocess(&bucketer, &points, &self.config, threads);
        {
            let mut em = self.embedder.write().unwrap();
            em.reload(pre.idf.clone(), pre.filter.clone());
        }
        // Re-index under the new embeddings.
        for p in points {
            let embedding = { self.embedder.read().unwrap().embed(&p) };
            self.index.upsert(p.id, embedding);
        }
        Ok(())
    }

    /// Snapshot of all stored points (persistence, periodic refresh).
    pub fn store_snapshot(&self) -> Vec<std::sync::Arc<Point>> {
        self.store.snapshot()
    }

    /// Current IDF/filter tables (persistence).
    pub fn tables(&self) -> (Option<crate::embed::IdfTable>, Option<crate::embed::PopularFilter>) {
        let e = self.embedder.read().unwrap();
        (e.idf().cloned(), e.filter().cloned())
    }

    /// Install explicit tables (snapshot restore) and re-index every stored
    /// point under the new embeddings.
    pub fn set_tables(
        &self,
        idf: Option<crate::embed::IdfTable>,
        filter: Option<crate::embed::PopularFilter>,
    ) -> Result<()> {
        {
            let mut em = self.embedder.write().unwrap();
            em.reload(idf, filter);
        }
        for p in self.store.snapshot() {
            let embedding = { self.embedder.read().unwrap().embed(&p) };
            self.index.upsert(p.id, embedding);
        }
        Ok(())
    }

    /// Service stats as JSON (the `stats` RPC). Cheap to serve per
    /// request: the index snapshot is O(shards) — every per-shard figure,
    /// including the byte estimate, is an incrementally-maintained counter
    /// (the old implementation walked every slot and posting list here).
    pub fn stats_json(&self) -> Json {
        let ix = self.index.stats();
        Json::obj(vec![
            ("points", Json::num(ix.live_points as f64)),
            ("live_postings", Json::num(ix.live_postings as f64)),
            ("dead_postings", Json::num(ix.dead_postings as f64)),
            ("distinct_dims", Json::num(ix.distinct_dims as f64)),
            ("slot_capacity", Json::num(ix.slot_capacity as f64)),
            ("postings_scanned", Json::u64(ix.postings_scanned)),
            ("index_bytes", Json::num(ix.approx_bytes as f64)),
            ("rss_bytes", Json::num(crate::metrics::current_rss_bytes() as f64)),
            ("peak_rss_bytes", Json::num(crate::metrics::peak_rss_bytes() as f64)),
            ("counters", self.metrics.counters.to_json()),
            ("mutation_latency", self.metrics.mutation_latency.summary().to_json()),
            ("query_latency", self.metrics.query_latency.summary().to_json()),
            ("scoring_latency", self.metrics.scoring_latency.summary().to_json()),
            ("staleness_p99_ms", Json::num(self.metrics.staleness.p99_ms())),
            ("replication", self.metrics.replication.to_json(self.wal_seq())),
            ("faults", crate::metrics::faults().to_json()),
            (
                "wal",
                match self.wal.get() {
                    Some(w) => Json::obj(vec![
                        ("dir", Json::str(w.dir().display().to_string())),
                        ("seq", Json::u64(w.seq())),
                        ("pending", Json::u64(w.pending())),
                    ]),
                    None => Json::Null,
                },
            ),
            ("config", self.config.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticConfig;

    fn boot(n: usize) -> (DynamicGus, crate::data::Dataset) {
        let ds = SyntheticConfig::arxiv_like(n, 21).generate();
        let config = GusConfig {
            scorer: ScorerKind::Native,
            filter_p: 0.0,
            ..GusConfig::default()
        };
        let gus = DynamicGus::bootstrap(ds.schema.clone(), config, &ds.points, 2).unwrap();
        (gus, ds)
    }

    #[test]
    fn bootstrap_indexes_all() {
        let (gus, ds) = boot(300);
        assert_eq!(gus.len(), 300);
        assert!(gus.contains(ds.points[5].id));
    }

    #[test]
    fn query_returns_cluster_mates() {
        let (gus, ds) = boot(400);
        // Query an existing point: its neighbors should mostly share its
        // cluster (the whole point of the system).
        let mut hits = 0usize;
        let mut total = 0usize;
        for qi in 0..30 {
            let res = gus.query(&ds.points[qi], 10).unwrap();
            for n in res {
                assert_ne!(n.id, ds.points[qi].id, "self returned");
                let ni = n.id as usize;
                total += 1;
                if ds.cluster_of[ni] == ds.cluster_of[qi] {
                    hits += 1;
                }
            }
        }
        assert!(total > 0);
        assert!(
            hits as f64 / total as f64 > 0.7,
            "cluster precision too low: {hits}/{total}"
        );
    }

    #[test]
    fn insert_then_visible_to_query() {
        let (gus, ds) = boot(200);
        // A brand-new point duplicated from an existing one must surface it.
        let mut newp = ds.points[0].clone();
        newp.id = 999_999;
        gus.insert(newp.clone()).unwrap();
        assert_eq!(gus.len(), 201);
        let res = gus.query(&ds.points[0], 5).unwrap();
        assert!(
            res.iter().any(|n| n.id == 999_999),
            "fresh insert not visible: {res:?}"
        );
    }

    #[test]
    fn delete_disappears() {
        let (gus, ds) = boot(200);
        let victim = ds.points[1].id;
        assert!(gus.delete(victim).unwrap());
        assert!(!gus.delete(victim).unwrap());
        assert!(!gus.contains(victim));
        for qi in 0..20 {
            let res = gus.query(&ds.points[qi], 20).unwrap();
            assert!(res.iter().all(|n| n.id != victim));
        }
    }

    #[test]
    fn update_moves_point() {
        let (gus, ds) = boot(200);
        // Move point 0 onto point 100's features: they become neighbors.
        let mut moved = ds.points[100].clone();
        moved.id = ds.points[0].id;
        let existed = gus.insert(moved).unwrap();
        assert!(existed);
        assert_eq!(gus.len(), 200);
        let res = gus.query(&ds.points[100], 5).unwrap();
        assert!(res.iter().any(|n| n.id == ds.points[0].id), "{res:?}");
    }

    #[test]
    fn query_by_id_and_unknown() {
        let (gus, ds) = boot(150);
        let res = gus.query_by_id(ds.points[3].id, 5).unwrap();
        assert!(!res.is_empty());
        assert!(gus.query_by_id(123_456_789, 5).is_err());
    }

    #[test]
    fn scores_sorted_desc() {
        let (gus, ds) = boot(200);
        let res = gus.query(&ds.points[0], 10).unwrap();
        for w in res.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn schema_violation_rejected() {
        let (gus, _) = boot(100);
        let bad = Point::new(1, vec![]);
        assert!(gus.insert(bad.clone()).is_err());
        assert!(gus.query(&bad, 5).is_err());
    }

    #[test]
    fn metrics_accumulate() {
        let (gus, ds) = boot(100);
        let _ = gus.query(&ds.points[0], 5);
        let _ = gus.query(&ds.points[1], 5);
        let mut p = ds.points[0].clone();
        p.id = 77_777;
        let _ = gus.insert(p);
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(gus.metrics.counters.queries.load(Relaxed), 2);
        assert_eq!(gus.metrics.counters.inserts.load(Relaxed), 1);
        assert_eq!(gus.metrics.query_latency.count(), 2);
        let js = gus.stats_json();
        assert_eq!(js.get("points").as_usize(), Some(101));
    }

    #[test]
    fn stats_expose_scoring_metrics() {
        let (gus, ds) = boot(200);
        let _ = gus.query(&ds.points[0], 10).unwrap();
        let _ = gus.query_batch(&ds.points[1..4], 10).unwrap();
        // One histogram entry per scored neighborhood (1 single + 3 batched).
        assert_eq!(gus.metrics.scoring_latency.count(), 4);
        use std::sync::atomic::Ordering::Relaxed;
        let pairs = gus.metrics.counters.pairs_scored.load(Relaxed);
        assert!(pairs > 0);
        let js = gus.stats_json();
        assert_eq!(
            js.get("scoring_latency").get("count").as_u64(),
            Some(4),
            "scoring_latency missing from stats"
        );
        assert!(
            js.get("counters").get("pairs_scored_ns").as_u64().unwrap() > 0,
            "pairs_scored_ns did not accumulate"
        );
    }

    #[test]
    fn stats_expose_replication_section() {
        let (gus, _) = boot(100);
        let js = gus.stats_json();
        let rep = js.get("replication");
        assert_eq!(rep.get("role").as_str(), Some("single"));
        assert_eq!(rep.get("wal_last_seq").as_u64(), Some(0));
        assert_eq!(rep.get("replication_lag_records").as_u64(), Some(0));
        assert!(rep.get("leader").is_null());
    }

    #[test]
    fn stats_expose_faults_section() {
        let (gus, _) = boot(100);
        let js = gus.stats_json();
        let f = js.get("faults");
        // Counters are process-global and other tests may bump them; the
        // section's shape is what this test pins down.
        assert!(f.get("injected").get("enospc").as_u64().is_some());
        assert!(f.get("injected").get("err").as_u64().is_some());
        assert!(f.get("injected").get("torn").as_u64().is_some());
        assert!(f.get("injected").get("crash").as_u64().is_some());
        assert!(f.get("backoff_retries").as_u64().is_some());
        assert!(f.get("circuit_open_windows").as_u64().is_some());
    }

    #[test]
    fn stats_expose_scan_counter() {
        let (gus, ds) = boot(150);
        let before = gus.stats_json().get("postings_scanned").as_u64().unwrap();
        let _ = gus.query(&ds.points[0], 5).unwrap();
        let after = gus.stats_json().get("postings_scanned").as_u64().unwrap();
        assert!(after > before, "scan counter did not advance: {before} -> {after}");
        assert!(gus.stats_json().get("distinct_dims").as_u64().unwrap() > 0);
        assert!(gus.stats_json().get("slot_capacity").as_u64().unwrap() >= 150);
    }

    #[test]
    fn insert_batch_matches_sequential_inserts() {
        let ds = SyntheticConfig::arxiv_like(300, 21).generate();
        let config = GusConfig {
            scorer: ScorerKind::Native,
            filter_p: 0.0,
            n_shards: 4,
            ..GusConfig::default()
        };
        let batch_gus =
            DynamicGus::bootstrap(ds.schema.clone(), config.clone(), &ds.points[..100], 2).unwrap();
        let seq_gus =
            DynamicGus::bootstrap(ds.schema.clone(), config, &ds.points[..100], 2).unwrap();
        let new_points: Vec<Point> = ds.points[100..250].to_vec();
        for p in &new_points {
            seq_gus.insert(p.clone()).unwrap();
        }
        let existed = batch_gus.insert_batch(new_points).unwrap();
        assert_eq!(existed.len(), 150);
        assert!(existed.iter().all(|&e| !e), "fresh points reported existing");
        assert_eq!(batch_gus.len(), seq_gus.len());
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(batch_gus.metrics.counters.inserts.load(Relaxed), 150);
        // Re-inserting via batch counts as updates and changes nothing.
        let existed = batch_gus.insert_batch(ds.points[100..120].to_vec()).unwrap();
        assert!(existed.iter().all(|&e| e));
        assert_eq!(batch_gus.metrics.counters.updates.load(Relaxed), 20);
        // Both services answer queries identically.
        for qi in (0..250).step_by(23) {
            let a = batch_gus.query(&ds.points[qi], 10).unwrap();
            let b = seq_gus.query(&ds.points[qi], 10).unwrap();
            assert_eq!(a, b, "query {qi} diverged");
        }
    }

    #[test]
    fn delete_batch_matches_sequential_deletes() {
        let (batch_gus, ds) = boot(200);
        let (seq_gus, _) = boot(200);
        let victims: Vec<u64> = ds.points[..40].iter().map(|p| p.id).collect();
        let want: Vec<bool> = victims.iter().map(|&id| seq_gus.delete(id).unwrap()).collect();
        let got = batch_gus.delete_batch(&victims).unwrap();
        assert_eq!(got, want);
        assert!(got.iter().all(|&e| e));
        assert_eq!(batch_gus.len(), seq_gus.len());
        for &id in &victims {
            assert!(!batch_gus.contains(id));
        }
        // Deleting again (including unknown ids) reports absent, harmlessly.
        let mut again = victims[..5].to_vec();
        again.push(987_654_321);
        let got = batch_gus.delete_batch(&again).unwrap();
        assert!(got.iter().all(|&e| !e));
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(batch_gus.metrics.counters.deletes.load(Relaxed), 46);
        assert!(batch_gus.delete_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn query_batch_matches_single_queries() {
        let (gus, ds) = boot(300);
        let queries: Vec<Point> = ds.points[..25].to_vec();
        let batch = gus.query_batch(&queries, 10).unwrap();
        assert_eq!(batch.len(), 25);
        for (i, p) in queries.iter().enumerate() {
            let single = gus.query(p, 10).unwrap();
            assert_eq!(batch[i], single, "query {i} diverged");
        }
        use std::sync::atomic::Ordering::Relaxed;
        // 25 batched + 25 singles.
        assert_eq!(gus.metrics.counters.queries.load(Relaxed), 50);
        assert!(gus.query_batch(&[], 10).unwrap().is_empty());
    }

    #[test]
    fn insert_batch_rejects_atomically() {
        let (gus, ds) = boot(100);
        let before = gus.len();
        let mut batch = vec![ds.points[0].clone()];
        batch[0].id = 55_001;
        batch.push(Point::new(55_002, vec![])); // schema violation
        assert!(gus.insert_batch(batch).is_err());
        assert_eq!(gus.len(), before, "partial batch applied");
        assert!(!gus.contains(55_001));
        assert!(!gus.contains(55_002));
        // query_batch validates the whole batch too.
        let bad = vec![ds.points[0].clone(), Point::new(1, vec![])];
        assert!(gus.query_batch(&bad, 5).is_err());
    }

    #[test]
    fn degraded_full_budget_matches_exact_query() {
        let (gus, ds) = boot(300);
        // frac = 1.0 on a single shard derives a budget of live_postings,
        // which cannot bind: the answer must equal the exact query.
        let spec = DegradeSpec { budget_frac: 1.0, skip_refine: false };
        for qi in (0..50).step_by(7) {
            let full = gus.query(&ds.points[qi], 10).unwrap();
            let deg = gus.query_degraded(&ds.points[qi], 10, spec).unwrap();
            assert_eq!(full, deg, "query {qi} diverged at full budget");
        }
    }

    #[test]
    fn degraded_skip_refine_ranks_by_dot() {
        let (gus, ds) = boot(300);
        let spec = DegradeSpec { budget_frac: 1.0, skip_refine: true };
        let res = gus.query_degraded(&ds.points[0], 10, spec).unwrap();
        assert!(!res.is_empty());
        for n in &res {
            assert_eq!(n.score, n.dot, "skip_refine must report score == dot");
        }
        for w in res.windows(2) {
            assert!(w[0].dot >= w[1].dot, "not dot-ranked: {res:?}");
        }
        // The candidate set matches the scored path's retrieval (same
        // budget): only the ordering criterion differs.
        let full = gus.query(&ds.points[0], 10).unwrap();
        let ids = |v: &[ScoredNeighbor]| {
            let mut x: Vec<u64> = v.iter().map(|n| n.id).collect();
            x.sort_unstable();
            x
        };
        assert_eq!(ids(&full), ids(&res));
    }

    #[test]
    fn degraded_budget_shrinks_scan_volume() {
        let (gus, ds) = boot(400);
        let scanned = |g: &DynamicGus| g.stats_json().get("postings_scanned").as_u64().unwrap();
        let before = scanned(&gus);
        let _ = gus.query(&ds.points[0], 10).unwrap();
        let full_scan = scanned(&gus) - before;
        let before = scanned(&gus);
        let spec = DegradeSpec { budget_frac: 0.02, skip_refine: false };
        let res = gus.query_degraded(&ds.points[0], 10, spec).unwrap();
        let degraded_scan = scanned(&gus) - before;
        // The index pre-slices posting lists to the budget, so the scan is
        // capped by ceil(live_postings × frac) — and well under the exact
        // query's volume.
        let live = gus.stats_json().get("live_postings").as_u64().unwrap();
        let budget = (live as f64 * 0.02).ceil() as u64;
        assert!(
            degraded_scan <= budget,
            "budget did not cap the scan: {degraded_scan} > {budget}"
        );
        assert!(
            degraded_scan < full_scan,
            "2% budget did not shrink the scan: {degraded_scan} vs {full_scan}"
        );
        // Still a useful answer, just a cheaper one.
        assert!(!res.is_empty());
    }

    #[test]
    fn query_batch_degraded_matches_singles() {
        let (gus, ds) = boot(300);
        for spec in [
            DegradeSpec { budget_frac: 0.5, skip_refine: false },
            DegradeSpec { budget_frac: 0.25, skip_refine: true },
        ] {
            let queries: Vec<Point> = ds.points[..12].to_vec();
            let batch = gus.query_batch_degraded(&queries, 8, spec).unwrap();
            assert_eq!(batch.len(), 12);
            for (i, p) in queries.iter().enumerate() {
                let single = gus.query_degraded(p, 8, spec).unwrap();
                assert_eq!(batch[i], single, "degraded batch query {i} diverged ({spec:?})");
            }
        }
        assert!(gus
            .query_batch_degraded(&[], 8, DegradeSpec { budget_frac: 0.5, skip_refine: false })
            .unwrap()
            .is_empty());
    }

    #[test]
    fn query_by_id_degraded_unknown_errors() {
        let (gus, ds) = boot(150);
        let spec = DegradeSpec { budget_frac: 0.5, skip_refine: true };
        assert!(gus.query_by_id_degraded(ds.points[3].id, 5, spec).unwrap().len() > 0);
        assert!(gus.query_by_id_degraded(123_456_789, 5, spec).is_err());
    }

    #[test]
    fn refresh_tables_keeps_service_consistent() {
        let ds = SyntheticConfig::products_like(300, 22).generate();
        let config = GusConfig {
            scorer: ScorerKind::Native,
            filter_p: 10.0,
            idf_s: 1000,
            ..GusConfig::default()
        };
        let gus = DynamicGus::bootstrap(ds.schema.clone(), config, &ds.points, 2).unwrap();
        let before = gus.query(&ds.points[0], 10).unwrap();
        gus.refresh_tables(2).unwrap();
        assert_eq!(gus.len(), 300);
        let after = gus.query(&ds.points[0], 10).unwrap();
        // Corpus unchanged ⇒ tables unchanged ⇒ same neighbor set.
        let ids = |v: &[ScoredNeighbor]| {
            let mut x: Vec<u64> = v.iter().map(|n| n.id).collect();
            x.sort_unstable();
            x
        };
        assert_eq!(ids(&before), ids(&after));
    }
}
