//! Feature store: the authoritative id → features map.
//!
//! The Neighborhood RPC needs candidate features to score retrieved points
//! (§3.3.3 — ScaNN returns "the closest points to p (and their features)").
//! Points are stored behind `Arc` so the query path borrows them without
//! copying feature vectors; the store is sharded like the index to keep
//! write contention off the query path.

use std::sync::{Arc, RwLock};

use crate::features::{Point, PointId};
use crate::util::hash::{mix64, FxHashMap};

/// Sharded `PointId → Arc<Point>` map.
pub struct FeatureStore {
    shards: Vec<RwLock<FxHashMap<PointId, Arc<Point>>>>,
}

impl FeatureStore {
    pub fn new(n_shards: usize) -> FeatureStore {
        assert!(n_shards >= 1);
        FeatureStore {
            shards: (0..n_shards)
                .map(|_| RwLock::new(FxHashMap::default()))
                .collect(),
        }
    }

    #[inline]
    fn shard_of(&self, id: PointId) -> usize {
        (mix64(id) % self.shards.len() as u64) as usize
    }

    /// Insert or replace; returns the previous value if any.
    pub fn put(&self, p: Point) -> Option<Arc<Point>> {
        let shard = self.shard_of(p.id);
        self.shards[shard]
            .write()
            .unwrap()
            .insert(p.id, Arc::new(p))
    }

    pub fn get(&self, id: PointId) -> Option<Arc<Point>> {
        self.shards[self.shard_of(id)]
            .read()
            .unwrap()
            .get(&id)
            .cloned()
    }

    /// Fetch many ids at once: `out[i]` corresponds to `ids[i]`. One pass
    /// over `ids` (each id hashed once), with each shard's read lock
    /// acquired lazily and held until the end of the call — at most one
    /// acquisition per shard (the per-candidate `get` path locks once per
    /// id). Holding several read guards is deadlock-free: every writer
    /// ([`put`]/[`remove`]) takes exactly one shard lock, so no
    /// hold-and-wait cycle exists. `out` is cleared and refilled — reuse
    /// it across calls.
    ///
    /// [`put`]: FeatureStore::put
    /// [`remove`]: FeatureStore::remove
    pub fn get_many(&self, ids: &[PointId], out: &mut Vec<Option<Arc<Point>>>) {
        out.clear();
        out.reserve(ids.len());
        let mut guards: Vec<Option<_>> = (0..self.shards.len()).map(|_| None).collect();
        for &id in ids {
            let si = self.shard_of(id);
            let g = guards[si].get_or_insert_with(|| self.shards[si].read().unwrap());
            out.push(g.get(&id).cloned());
        }
    }

    pub fn remove(&self, id: PointId) -> Option<Arc<Point>> {
        self.shards[self.shard_of(id)].write().unwrap().remove(&id)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of all points (periodic table refresh; offline exports).
    pub fn snapshot(&self) -> Vec<Arc<Point>> {
        let mut out = Vec::with_capacity(self.len());
        for s in &self.shards {
            out.extend(s.read().unwrap().values().cloned());
        }
        out.sort_unstable_by_key(|p| p.id); // deterministic order
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::FeatureValue;

    fn pt(id: u64) -> Point {
        Point::new(id, vec![FeatureValue::Scalar(id as f32)])
    }

    #[test]
    fn put_get_remove() {
        let s = FeatureStore::new(4);
        assert!(s.put(pt(1)).is_none());
        assert!(s.put(pt(2)).is_none());
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(1).unwrap().id, 1);
        let old = s.put(pt(1)).unwrap();
        assert_eq!(old.id, 1);
        assert_eq!(s.len(), 2);
        assert_eq!(s.remove(1).unwrap().id, 1);
        assert!(s.get(1).is_none());
        assert!(s.remove(1).is_none());
    }

    #[test]
    fn get_many_matches_get() {
        let s = FeatureStore::new(4);
        for id in 0..50u64 {
            s.put(pt(id));
        }
        // Mix of present, absent and duplicate ids; buffer reused.
        let mut out = Vec::new();
        for ids in [
            vec![3u64, 999, 7, 7, 0, 49, 1234],
            vec![],
            vec![48, 2, 2, 100],
        ] {
            s.get_many(&ids, &mut out);
            assert_eq!(out.len(), ids.len());
            for (i, &id) in ids.iter().enumerate() {
                assert_eq!(
                    out[i].as_ref().map(|p| p.id),
                    s.get(id).map(|p| p.id),
                    "id {id}"
                );
            }
        }
    }

    #[test]
    fn snapshot_sorted_complete() {
        let s = FeatureStore::new(3);
        for id in [5u64, 1, 9, 3] {
            s.put(pt(id));
        }
        let snap = s.snapshot();
        let ids: Vec<u64> = snap.iter().map(|p| p.id).collect();
        assert_eq!(ids, vec![1, 3, 5, 9]);
    }

    #[test]
    fn concurrent_access() {
        let s = Arc::new(FeatureStore::new(4));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for i in 0..200u64 {
                    let id = t * 1000 + i;
                    s.put(pt(id));
                    assert!(s.get(id).is_some());
                    if i % 2 == 0 {
                        s.remove(id);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.len(), 4 * 100);
    }
}
