//! Service snapshot / restore.
//!
//! Industrial deployments restart; §4.3's "initial set of points" is, on
//! restart, the previous incarnation's corpus. A snapshot is the service
//! config plus the full feature store (points JSONL — same format as
//! `data::loader`); restore replays bootstrap: preprocessing tables and the
//! index are recomputed deterministically from the points (the LSH seed is
//! part of the config), so the restored service answers queries identically.

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::GusConfig;
use crate::coordinator::DynamicGus;
use crate::data::{loader, Dataset};
use crate::features::Schema;
use crate::util::json::Json;

/// Write `gus`'s current corpus + config under `dir/`
/// (`snapshot.json` + `points.jsonl`).
pub fn save(gus: &DynamicGus, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let (idf, filter) = gus.tables();
    let meta = Json::obj(vec![
        ("schema", Json::str(gus.schema().name.clone())),
        (
            "dense_dim",
            Json::num(gus.schema().primary_dense_dim() as f64),
        ),
        ("config", gus.config().to_json()),
        ("points", Json::num(gus.len() as f64)),
        // Tables are persisted, not recomputed: the restored service must
        // answer queries identically even though its corpus has drifted
        // from the bootstrap corpus the tables were derived from.
        ("idf", idf.map(|t| t.to_json()).unwrap_or(Json::Null)),
        ("filter", filter.map(|f| f.to_json()).unwrap_or(Json::Null)),
    ]);
    std::fs::write(dir.join("snapshot.json"), meta.dump())
        .with_context(|| format!("writing {}/snapshot.json", dir.display()))?;
    let snapshot = gus.store_snapshot();
    let ds = Dataset {
        schema: gus.schema().clone(),
        points: snapshot.iter().map(|p| (**p).clone()).collect(),
        cluster_of: Vec::new(),
    };
    loader::save(&ds, &dir.join("points.jsonl"))?;
    Ok(())
}

/// Restore a service from a snapshot directory.
pub fn restore(dir: &Path, threads: usize) -> Result<DynamicGus> {
    let meta_text = std::fs::read_to_string(dir.join("snapshot.json"))
        .with_context(|| format!("reading {}/snapshot.json", dir.display()))?;
    let meta = Json::parse(&meta_text).map_err(|e| anyhow::anyhow!("snapshot.json: {e}"))?;
    let config = GusConfig::from_json(meta.get("config"))
        .map_err(|e| anyhow::anyhow!("snapshot config: {e}"))?;
    let schema_name = meta
        .get("schema")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("snapshot missing schema"))?;
    let dense_dim = meta
        .get("dense_dim")
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("snapshot missing dense_dim"))?;
    let schema = match schema_name {
        "arxiv_like" => Schema::arxiv_like(dense_dim),
        "products_like" => Schema::products_like(dense_dim),
        other => anyhow::bail!("unknown schema '{other}'"),
    };
    let ds = loader::load(&dir.join("points.jsonl"))?;
    anyhow::ensure!(ds.schema == schema, "snapshot schema mismatch");
    let expect = meta.get("points").as_usize().unwrap_or(ds.points.len());
    anyhow::ensure!(
        ds.points.len() == expect,
        "snapshot truncated: {} of {expect} points",
        ds.points.len()
    );
    let gus = DynamicGus::bootstrap(schema, config, &ds.points, threads)?;
    // Replace the recomputed tables with the persisted ones.
    let idf = match meta.get("idf") {
        Json::Null => None,
        j => Some(
            crate::embed::IdfTable::from_json(j)
                .ok_or_else(|| anyhow::anyhow!("snapshot: bad idf table"))?,
        ),
    };
    let filter = match meta.get("filter") {
        Json::Null => None,
        j => Some(
            crate::embed::PopularFilter::from_json(j)
                .ok_or_else(|| anyhow::anyhow!("snapshot: bad filter"))?,
        ),
    };
    gus.set_tables(idf, filter)?;
    Ok(gus)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScorerKind;
    use crate::data::synthetic::SyntheticConfig;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("gus-snapshot-tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn snapshot_restore_round_trip() {
        let ds = SyntheticConfig::arxiv_like(300, 0x5a).generate();
        let cfg = GusConfig {
            scorer: ScorerKind::Native,
            filter_p: 10.0,
            ..GusConfig::default()
        };
        let gus =
            DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points[..250], 2).unwrap();
        // Mutate after bootstrap so the snapshot differs from the corpus.
        for p in &ds.points[250..] {
            gus.insert(p.clone()).unwrap();
        }
        gus.delete(ds.points[0].id).unwrap();

        let dir = tmpdir("roundtrip");
        save(&gus, &dir).unwrap();
        let restored = restore(&dir, 2).unwrap();
        assert_eq!(restored.len(), gus.len());
        assert!(!restored.contains(ds.points[0].id));
        // Identical answers (same LSH seed + tables recomputed from the
        // same corpus).
        for qi in (1..ds.points.len()).step_by(41) {
            assert_eq!(
                gus.query(&ds.points[qi], 10).unwrap(),
                restored.query(&ds.points[qi], 10).unwrap(),
                "query {qi} differs after restore"
            );
        }
    }

    #[test]
    fn restore_missing_dir_errors() {
        assert!(restore(Path::new("/nonexistent/snap"), 1).is_err());
    }

    #[test]
    fn restore_detects_truncation() {
        let ds = SyntheticConfig::arxiv_like(50, 0x5b).generate();
        let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
        let gus = DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 1).unwrap();
        let dir = tmpdir("truncated");
        save(&gus, &dir).unwrap();
        // Truncate points.jsonl.
        let path = dir.join("points.jsonl");
        let text = std::fs::read_to_string(&path).unwrap();
        let keep: Vec<&str> = text.lines().take(10).collect();
        std::fs::write(&path, keep.join("\n")).unwrap();
        let err = match restore(&dir, 1) {
            Ok(_) => panic!("expected truncation error"),
            Err(e) => e,
        };
        assert!(format!("{err}").contains("truncated"), "{err}");
    }
}
