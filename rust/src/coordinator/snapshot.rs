//! Service checkpoint / restore.
//!
//! Industrial deployments restart; §4.3's "initial set of points" is, on
//! restart, the previous incarnation's corpus. A checkpoint is the service
//! config plus the full feature store (points JSONL — same format as
//! `data::loader`) plus the embedding tables; restore replays bootstrap:
//! the index is recomputed deterministically from the points (the LSH seed
//! is part of the config) and the persisted tables are swapped in, so the
//! restored service answers queries identically.
//!
//! # Crash atomicity and the WAL
//!
//! With [`crate::coordinator::wal`] enabled, [`save_with_seq`] is the slow
//! half of an *incremental checkpoint*: the corpus is written to a
//! `points-<seq>.jsonl` file first, then `snapshot.json` — which names
//! that file and records `last_seq`, the WAL sequence number the snapshot
//! includes — is renamed into place atomically. The rename is the commit
//! point: a crash at any earlier moment leaves the previous checkpoint
//! (and the untruncated WAL) fully intact. Recovery replays only WAL
//! records with `seq > last_seq`, so the checkpoint-then-truncate pair in
//! [`DynamicGus::checkpoint`] is safe at every intermediate step.

use std::path::Path;

use anyhow::{Context, Result};

use crate::config::GusConfig;
use crate::coordinator::DynamicGus;
use crate::data::{loader, Dataset};
use crate::fault::injector::{enact_crash, injected_error};
use crate::fault::{FaultInjector, FaultKind, FaultSite};
use crate::features::Schema;
use crate::util::json::Json;

/// Checkpoint metadata file name (its presence commits a checkpoint).
pub const SNAPSHOT_META: &str = "snapshot.json";

/// Resolve a persisted schema name back to a [`Schema`] (shared by
/// snapshot restore and WAL-only recovery).
pub fn schema_by_name(name: &str, dense_dim: usize) -> Result<Schema> {
    match name {
        "arxiv_like" => Ok(Schema::arxiv_like(dense_dim)),
        "products_like" => Ok(Schema::products_like(dense_dim)),
        other => anyhow::bail!("unknown schema '{other}'"),
    }
}

/// Force a file's contents to stable storage (any fd of the file flushes
/// its dirty pages).
fn fsync_path(path: &Path) -> Result<()> {
    std::fs::File::open(path)
        .and_then(|f| f.sync_all())
        .with_context(|| format!("fsync {}", path.display()))
}

/// Force directory entries (the renames) to stable storage. Best effort:
/// not every platform can fsync a directory.
fn fsync_dir(dir: &Path) {
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Write `gus`'s current corpus + config + tables under `dir/`. Records
/// the service's current WAL sequence number when a WAL is attached — on
/// a live durable service prefer [`DynamicGus::checkpoint`], which also
/// truncates the log under the WAL lock.
pub fn save(gus: &DynamicGus, dir: &Path) -> Result<()> {
    save_with_seq(gus, dir, gus.wal_seq())
}

/// Write a checkpoint declaring that every mutation with WAL sequence
/// number ≤ `last_seq` is included. Committed by an atomic rename of
/// `snapshot.json`; never corrupts a previous checkpoint mid-write.
/// Consults the process-global fault injector (if armed) at the commit
/// rename — `checkpoint_rename` plan rules fire here.
pub fn save_with_seq(gus: &DynamicGus, dir: &Path, last_seq: u64) -> Result<()> {
    save_with_seq_injected(gus, dir, last_seq, crate::fault::global().as_deref())
}

/// [`save_with_seq`] with an explicit fault injector for the
/// `checkpoint_rename` site. [`DynamicGus::checkpoint`] passes its WAL
/// writer's captured injector so tests can target one service without
/// arming the once-per-process global plan.
pub fn save_with_seq_injected(
    gus: &DynamicGus,
    dir: &Path,
    last_seq: u64,
    faults: Option<&FaultInjector>,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    // 1. Corpus, to a per-sequence file the metadata will point at.
    //    (tmp + rename so a crash mid-write never clobbers the file a
    //    committed snapshot.json already references).
    let points_file = format!("points-{last_seq}.jsonl");
    let snapshot = gus.store_snapshot();
    let ds = Dataset {
        schema: gus.schema().clone(),
        points: snapshot.iter().map(|p| (**p).clone()).collect(),
        cluster_of: Vec::new(),
    };
    let points_tmp = dir.join(format!("{points_file}.tmp"));
    loader::save(&ds, &points_tmp)?;
    // fsync before each rename: once the WAL is truncated, the snapshot
    // is the only copy of these mutations — it must survive power loss,
    // not just process death.
    fsync_path(&points_tmp)?;
    std::fs::rename(&points_tmp, dir.join(&points_file))
        .with_context(|| format!("committing {}/{points_file}", dir.display()))?;

    // 2. Metadata — the commit point.
    let (idf, filter) = gus.tables();
    let meta = Json::obj(vec![
        ("schema", Json::str(gus.schema().name.clone())),
        (
            "dense_dim",
            Json::num(gus.schema().primary_dense_dim() as f64),
        ),
        ("config", gus.config().to_json()),
        ("points", Json::num(ds.points.len() as f64)),
        ("points_file", Json::str(points_file.clone())),
        ("last_seq", Json::u64(last_seq)),
        // Tables are persisted, not recomputed: the restored service must
        // answer queries identically even though its corpus has drifted
        // from the bootstrap corpus the tables were derived from.
        ("idf", idf.map(|t| t.to_json()).unwrap_or(Json::Null)),
        ("filter", filter.map(|f| f.to_json()).unwrap_or(Json::Null)),
    ]);
    let meta_tmp = dir.join("snapshot.json.tmp");
    std::fs::write(&meta_tmp, meta.dump())
        .with_context(|| format!("writing {}", meta_tmp.display()))?;
    fsync_path(&meta_tmp)?;
    // The rename below is the checkpoint's commit point, so this is the
    // sharpest place to fail: everything is written and fsynced, only
    // the commit is missing. A crash/error here must leave the previous
    // checkpoint (and the untruncated WAL) authoritative.
    if let Some(kind) = faults.and_then(|f| f.check(FaultSite::CheckpointRename, last_seq)) {
        if kind == FaultKind::Crash {
            enact_crash(FaultSite::CheckpointRename);
        }
        return Err(injected_error(FaultSite::CheckpointRename, kind)
            .context(format!("committing {}/{SNAPSHOT_META}", dir.display())));
    }
    std::fs::rename(&meta_tmp, dir.join(SNAPSHOT_META))
        .with_context(|| format!("committing {}/{SNAPSHOT_META}", dir.display()))?;
    fsync_dir(dir);

    // 3. Best-effort cleanup of corpus files no longer referenced.
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            let name = e.file_name().to_string_lossy().into_owned();
            let stale_versioned = name.starts_with("points-")
                && name.ends_with(".jsonl")
                && name != points_file;
            let stale_legacy = name == "points.jsonl";
            if stale_versioned || stale_legacy {
                let _ = std::fs::remove_file(e.path());
            }
        }
    }
    Ok(())
}

/// Restore a service from a checkpoint directory.
pub fn restore(dir: &Path, threads: usize) -> Result<DynamicGus> {
    restore_with_seq(dir, threads).map(|(gus, _)| gus)
}

/// Restore a service and report the checkpoint's `last_seq` (the WAL
/// sequence number up to which it is complete; 0 for legacy snapshots).
pub fn restore_with_seq(dir: &Path, threads: usize) -> Result<(DynamicGus, u64)> {
    let meta_text = std::fs::read_to_string(dir.join(SNAPSHOT_META))
        .with_context(|| format!("reading {}/{SNAPSHOT_META}", dir.display()))?;
    let meta = Json::parse(&meta_text).map_err(|e| anyhow::anyhow!("{SNAPSHOT_META}: {e}"))?;
    let config = GusConfig::from_json(meta.get("config"))
        .map_err(|e| anyhow::anyhow!("snapshot config: {e}"))?;
    let schema_name = meta
        .get("schema")
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("snapshot missing schema"))?;
    let dense_dim = meta
        .get("dense_dim")
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("snapshot missing dense_dim"))?;
    let schema = schema_by_name(schema_name, dense_dim)?;
    // Legacy (pre-WAL) snapshots stored the corpus as `points.jsonl`.
    let points_file = meta.get("points_file").as_str().unwrap_or("points.jsonl");
    let ds = loader::load(&dir.join(points_file))?;
    anyhow::ensure!(ds.schema == schema, "snapshot schema mismatch");
    let expect = meta.get("points").as_usize().unwrap_or(ds.points.len());
    anyhow::ensure!(
        ds.points.len() == expect,
        "snapshot truncated: {} of {expect} points",
        ds.points.len()
    );
    let gus = DynamicGus::bootstrap(schema, config, &ds.points, threads)?;
    // Replace the recomputed tables with the persisted ones.
    let idf = match meta.get("idf") {
        Json::Null => None,
        j => Some(
            crate::embed::IdfTable::from_json(j)
                .ok_or_else(|| anyhow::anyhow!("snapshot: bad idf table"))?,
        ),
    };
    let filter = match meta.get("filter") {
        Json::Null => None,
        j => Some(
            crate::embed::PopularFilter::from_json(j)
                .ok_or_else(|| anyhow::anyhow!("snapshot: bad filter"))?,
        ),
    };
    gus.set_tables(idf, filter)?;
    let last_seq = meta.get("last_seq").as_u64().unwrap_or(0);
    Ok((gus, last_seq))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ScorerKind;
    use crate::data::synthetic::SyntheticConfig;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("gus-snapshot-tests").join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn snapshot_restore_round_trip() {
        let ds = SyntheticConfig::arxiv_like(300, 0x5a).generate();
        let cfg = GusConfig {
            scorer: ScorerKind::Native,
            filter_p: 10.0,
            ..GusConfig::default()
        };
        let gus =
            DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points[..250], 2).unwrap();
        // Mutate after bootstrap so the snapshot differs from the corpus.
        for p in &ds.points[250..] {
            gus.insert(p.clone()).unwrap();
        }
        gus.delete(ds.points[0].id).unwrap();

        let dir = tmpdir("roundtrip");
        save(&gus, &dir).unwrap();
        let restored = restore(&dir, 2).unwrap();
        assert_eq!(restored.len(), gus.len());
        assert!(!restored.contains(ds.points[0].id));
        // Identical answers (same LSH seed + tables recomputed from the
        // same corpus).
        for qi in (1..ds.points.len()).step_by(41) {
            assert_eq!(
                gus.query(&ds.points[qi], 10).unwrap(),
                restored.query(&ds.points[qi], 10).unwrap(),
                "query {qi} differs after restore"
            );
        }
    }

    #[test]
    fn restore_missing_dir_errors() {
        assert!(restore(Path::new("/nonexistent/snap"), 1).is_err());
    }

    #[test]
    fn save_commits_atomically_and_cleans_up() {
        let ds = SyntheticConfig::arxiv_like(60, 0x5c).generate();
        let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
        let gus = DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 1).unwrap();
        let dir = tmpdir("atomic");
        save_with_seq(&gus, &dir, 3).unwrap();
        assert!(dir.join("points-3.jsonl").exists());
        // A second checkpoint at a later seq replaces the corpus file and
        // removes the stale one; no tmp files survive.
        save_with_seq(&gus, &dir, 9).unwrap();
        assert!(dir.join("points-9.jsonl").exists());
        assert!(!dir.join("points-3.jsonl").exists());
        for e in std::fs::read_dir(&dir).unwrap().flatten() {
            assert!(
                !e.file_name().to_string_lossy().ends_with(".tmp"),
                "tmp file left behind: {:?}",
                e.file_name()
            );
        }
        let (restored, last_seq) = restore_with_seq(&dir, 1).unwrap();
        assert_eq!(last_seq, 9);
        assert_eq!(restored.len(), 60);
    }

    #[test]
    fn restore_reads_legacy_points_file() {
        // Pre-WAL snapshots named the corpus `points.jsonl` and had no
        // `points_file` / `last_seq` fields.
        let ds = SyntheticConfig::arxiv_like(40, 0x5d).generate();
        let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
        let gus = DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 1).unwrap();
        let dir = tmpdir("legacy");
        save(&gus, &dir).unwrap();
        // Rewrite the dir into the legacy shape.
        std::fs::rename(dir.join("points-0.jsonl"), dir.join("points.jsonl")).unwrap();
        let meta_text = std::fs::read_to_string(dir.join(SNAPSHOT_META)).unwrap();
        let meta = Json::parse(&meta_text).unwrap();
        let mut obj = meta.as_obj().unwrap().clone();
        obj.remove("points_file");
        obj.remove("last_seq");
        std::fs::write(dir.join(SNAPSHOT_META), Json::Obj(obj).dump()).unwrap();
        let (restored, last_seq) = restore_with_seq(&dir, 1).unwrap();
        assert_eq!(last_seq, 0);
        assert_eq!(restored.len(), 40);
    }

    #[test]
    fn restore_detects_truncation() {
        let ds = SyntheticConfig::arxiv_like(50, 0x5b).generate();
        let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
        let gus = DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 1).unwrap();
        let dir = tmpdir("truncated");
        save(&gus, &dir).unwrap();
        // Truncate the corpus file named by the metadata.
        let meta_text = std::fs::read_to_string(dir.join(SNAPSHOT_META)).unwrap();
        let meta = Json::parse(&meta_text).unwrap();
        let path = dir.join(meta.get("points_file").as_str().unwrap());
        let text = std::fs::read_to_string(&path).unwrap();
        let keep: Vec<&str> = text.lines().take(10).collect();
        std::fs::write(&path, keep.join("\n")).unwrap();
        let err = match restore(&dir, 1) {
            Ok(_) => panic!("expected truncation error"),
            Err(e) => e,
        };
        assert!(format!("{err}").contains("truncated"), "{err}");
    }
}
