//! High-throughput mutation ingestion.
//!
//! The paper's capability claim: "hundreds of thousands of new points with
//! their respective features can be inserted, modified, or deleted per
//! second". A single synchronous mutation costs ~20 µs (embed + index
//! upsert + store put), i.e. ~50k/s on one core; the paper's rates need the
//! parallel path. This pipeline fans mutations out to a worker pool over a
//! **bounded queue** (backpressure: `submit` blocks when the queue is full,
//! so producers can't outrun the index without noticing), preserving
//! per-point ordering by routing each point id to a fixed worker.
//!
//! Freshness semantics: a mutation is visible to queries once its worker
//! applies it; [`IngestPipeline::flush`] gives a barrier ("everything
//! submitted before this call is now visible") — the tool for bounding the
//! paper's p99 staleness under bulk load.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::DynamicGus;
use crate::features::{Point, PointId};
use crate::util::hash::mix64;

/// A mutation for the bulk path.
#[derive(Debug, Clone)]
pub enum Mutation {
    Upsert(Point),
    Delete(PointId),
}

impl Mutation {
    fn id(&self) -> PointId {
        match self {
            Mutation::Upsert(p) => p.id,
            Mutation::Delete(id) => *id,
        }
    }
}

/// Queue contents and the closed flag live under ONE mutex: keeping
/// `closed` under its own lock (as an earlier revision did) loses wakeups —
/// `close` can set the flag and notify between `pop`'s closed-check and its
/// wait, leaving the popper asleep forever. The condvar predicate must be
/// guarded by the mutex the wait releases.
struct QueueState {
    buf: std::collections::VecDeque<Mutation>,
    closed: bool,
}

struct Queue {
    state: Mutex<QueueState>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

impl Queue {
    fn new(capacity: usize) -> Queue {
        Queue {
            state: Mutex::new(QueueState {
                buf: std::collections::VecDeque::with_capacity(capacity),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push (backpressure).
    fn push(&self, m: Mutation) {
        let mut st = self.state.lock().unwrap();
        while st.buf.len() >= self.capacity {
            st = self.not_full.wait(st).unwrap();
        }
        st.buf.push_back(m);
        drop(st);
        self.not_empty.notify_one();
    }

    /// Blocking pop; `None` once closed and drained.
    fn pop(&self) -> Option<Mutation> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(m) = st.buf.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(m);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }

    fn len(&self) -> usize {
        self.state.lock().unwrap().buf.len()
    }
}

/// Parallel ingest pipeline over a [`DynamicGus`] service.
pub struct IngestPipeline {
    queues: Vec<Arc<Queue>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    applied: Arc<AtomicU64>,
    submitted: AtomicU64,
    errors: Arc<AtomicU64>,
}

impl IngestPipeline {
    /// Spawn `n_workers` appliers against the service. `queue_capacity` is
    /// per worker (total buffering = n_workers × capacity).
    pub fn new(gus: Arc<DynamicGus>, n_workers: usize, queue_capacity: usize) -> IngestPipeline {
        let n_workers = n_workers.max(1);
        let queues: Vec<Arc<Queue>> = (0..n_workers)
            .map(|_| Arc::new(Queue::new(queue_capacity.max(1))))
            .collect();
        let applied = Arc::new(AtomicU64::new(0));
        let errors = Arc::new(AtomicU64::new(0));
        let workers = queues
            .iter()
            .enumerate()
            .map(|(i, q)| {
                let q = Arc::clone(q);
                let gus = Arc::clone(&gus);
                let applied = Arc::clone(&applied);
                let errors = Arc::clone(&errors);
                std::thread::Builder::new()
                    .name(format!("gus-ingest-{i}"))
                    .spawn(move || {
                        while let Some(m) = q.pop() {
                            let r = match m {
                                Mutation::Upsert(p) => gus.insert(p).map(|_| ()),
                                Mutation::Delete(id) => gus.delete(id).map(|_| ()),
                            };
                            if r.is_err() {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                            applied.fetch_add(1, Ordering::Relaxed);
                        }
                    })
                    .expect("spawn ingest worker")
            })
            .collect();
        IngestPipeline {
            queues,
            workers,
            applied,
            submitted: AtomicU64::new(0),
            errors,
        }
    }

    /// Submit a mutation; blocks under backpressure. Mutations for the same
    /// point id always go to the same worker (per-point ordering).
    pub fn submit(&self, m: Mutation) {
        let shard = (mix64(m.id()) % self.queues.len() as u64) as usize;
        self.queues[shard].push(m);
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Barrier: wait until everything submitted so far is applied.
    pub fn flush(&self) {
        let target = self.submitted.load(Ordering::SeqCst);
        while self.applied.load(Ordering::SeqCst) < target {
            std::thread::yield_now();
        }
    }

    /// Mutations applied so far.
    pub fn applied(&self) -> u64 {
        self.applied.load(Ordering::Relaxed)
    }

    /// Mutations rejected by the service (schema violations etc.).
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// Total currently buffered (diagnostics / backpressure monitoring).
    pub fn backlog(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Drain and stop the workers.
    pub fn shutdown(mut self) {
        for q in &self.queues {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for IngestPipeline {
    fn drop(&mut self) {
        for q in &self.queues {
            q.close();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GusConfig, ScorerKind};
    use crate::data::synthetic::SyntheticConfig;

    fn boot(n_shards: usize) -> (Arc<DynamicGus>, crate::data::Dataset) {
        let ds = SyntheticConfig::arxiv_like(2_000, 0x1e).generate();
        let cfg = GusConfig {
            scorer: ScorerKind::Native,
            n_shards,
            ..GusConfig::default()
        };
        let gus = DynamicGus::bootstrap(ds.schema.clone(), cfg, &[], 2).unwrap();
        (Arc::new(gus), ds)
    }

    #[test]
    fn bulk_insert_applies_everything() {
        let (gus, ds) = boot(8);
        let pipeline = IngestPipeline::new(Arc::clone(&gus), 4, 256);
        for p in &ds.points {
            pipeline.submit(Mutation::Upsert(p.clone()));
        }
        pipeline.flush();
        assert_eq!(gus.len(), ds.points.len());
        assert_eq!(pipeline.applied(), ds.points.len() as u64);
        assert_eq!(pipeline.errors(), 0);
        pipeline.shutdown();
    }

    #[test]
    fn per_point_ordering_upsert_then_delete() {
        let (gus, ds) = boot(8);
        let pipeline = IngestPipeline::new(Arc::clone(&gus), 4, 64);
        // Insert then delete the same id, many times: final state must be
        // "deleted" because same-id mutations are ordered.
        for _ in 0..50 {
            for p in ds.points.iter().take(20) {
                pipeline.submit(Mutation::Upsert(p.clone()));
                pipeline.submit(Mutation::Delete(p.id));
            }
        }
        pipeline.flush();
        for p in ds.points.iter().take(20) {
            assert!(!gus.contains(p.id), "point {} resurrected", p.id);
        }
        pipeline.shutdown();
    }

    #[test]
    fn flush_is_a_visibility_barrier() {
        let (gus, ds) = boot(4);
        let pipeline = IngestPipeline::new(Arc::clone(&gus), 4, 128);
        for p in ds.points.iter().take(500) {
            pipeline.submit(Mutation::Upsert(p.clone()));
        }
        pipeline.flush();
        // Everything visible to queries now.
        for p in ds.points.iter().take(20) {
            assert!(gus.contains(p.id));
        }
        pipeline.shutdown();
    }

    #[test]
    fn errors_counted_not_fatal() {
        let (gus, ds) = boot(4);
        let pipeline = IngestPipeline::new(Arc::clone(&gus), 2, 64);
        pipeline.submit(Mutation::Upsert(crate::features::Point::new(1, vec![])));
        pipeline.submit(Mutation::Upsert(ds.points[0].clone()));
        pipeline.flush();
        assert_eq!(pipeline.errors(), 1);
        assert_eq!(gus.len(), 1);
        pipeline.shutdown();
    }

    #[test]
    fn backpressure_bounds_backlog() {
        let (gus, ds) = boot(4);
        let cap = 8usize;
        let pipeline = IngestPipeline::new(Arc::clone(&gus), 2, cap);
        for p in &ds.points {
            pipeline.submit(Mutation::Upsert(p.clone()));
            assert!(pipeline.backlog() <= 2 * cap + 2, "backlog exploded");
        }
        pipeline.flush();
        assert_eq!(gus.len(), ds.points.len());
        pipeline.shutdown();
    }

    #[test]
    #[ignore = "timing-sensitive: run explicitly (cargo test -- --ignored) on an idle machine; the scaling claim is also covered by benches/insertion.rs"]
    fn parallel_ingest_throughput_exceeds_sequential() {
        // The paper's rate claim, shape-level: 8 workers on a sharded
        // service must beat 1 worker clearly.
        let measure = |workers: usize| -> f64 {
            let (gus, ds) = boot(16);
            let pipeline = IngestPipeline::new(Arc::clone(&gus), workers, 512);
            let t0 = std::time::Instant::now();
            for p in &ds.points {
                pipeline.submit(Mutation::Upsert(p.clone()));
            }
            pipeline.flush();
            let dt = t0.elapsed().as_secs_f64();
            pipeline.shutdown();
            ds.points.len() as f64 / dt
        };
        let seq = measure(1);
        let par = measure(8);
        assert!(
            par > seq * 1.5,
            "parallel ingest did not scale: {par:.0}/s vs {seq:.0}/s"
        );
    }
}
