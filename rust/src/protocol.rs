//! Typed wire protocol — the one source of truth for what goes over the
//! socket and into the write-ahead log.
//!
//! Every byte the server reads or writes, every WAL payload, and every
//! request `gus replay` re-executes decodes and encodes through this
//! module: [`Request`] / [`Response`] enums with a single
//! [`Request::from_wire`] / [`Request::to_wire`] path, a versioned
//! envelope ([`Envelope`]) for pipelined multiplexed serving, and
//! machine-readable error codes ([`ErrorCode`]).
//!
//! # Two dialects, one decoder
//!
//! **Legacy** (protocol v0, still fully served): a bare op object per
//! line, answered strictly in order with un-enveloped responses:
//!
//! ```text
//! → {"op":"query_id","id":3,"k":5}
//! ← {"ok":true,"neighbors":[...]}
//! ```
//!
//! **v1**: the same op object nested under `req`, wrapped in an envelope
//! carrying a client-chosen correlation `id` and an optional relative
//! deadline. Responses echo `id` and may arrive out of order:
//!
//! ```text
//! → {"v":1,"id":7,"deadline_ms":50,"req":{"op":"query_id","id":3,"k":5}}
//! ← {"v":1,"id":7,"ok":true,"neighbors":[...]}
//! ```
//!
//! The op object is *byte-identical* across the two dialects and the WAL
//! (the envelope nests it verbatim rather than inlining its fields —
//! `delete`/`query_id` already use `"id"` for the point id, so inlining
//! would collide with the envelope's correlation id). Dialect detection
//! is the presence of the `"v"` key.
//!
//! # Error codes
//!
//! | code                | meaning                                          |
//! |---------------------|--------------------------------------------------|
//! | `BAD_REQUEST`       | malformed line, unknown op, bad field, schema violation |
//! | `NOT_FOUND`         | `query_id` of an absent point                    |
//! | `UNAVAILABLE`       | op unsupported in this server state (e.g. `checkpoint` without a WAL), or server shutting down |
//! | `DEADLINE_EXCEEDED` | the request's deadline expired before execution  |
//! | `OVERLOADED`        | shed by admission control (queue or connection cap) |
//! | `NOT_LEADER`        | mutation sent to a read-only replica; the message carries a `leader=<addr>` hint |
//!
//! Validation happens at decode time: `k = 0` or `k >` [`MAX_K`] is a
//! `BAD_REQUEST` before the index is ever touched.

use std::fmt;

use crate::admission::Class;
use crate::coordinator::ScoredNeighbor;
use crate::features::Point;
use crate::util::json::Json;

/// The protocol version this build speaks (and the only one it accepts
/// in an envelope).
pub const VERSION: u64 = 1;

/// Upper bound on `k` accepted by the query ops. Requests beyond it are
/// rejected at decode time with `BAD_REQUEST` — a `k` in the billions is
/// a client bug (or an attack), not a neighborhood size, and would
/// otherwise size retrieval buffers.
pub const MAX_K: usize = 65_536;

// ---------- error codes ----------

/// Machine-readable failure classification carried by every error
/// response (`{"ok":false,"code":...,"error":...}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    BadRequest,
    NotFound,
    Unavailable,
    DeadlineExceeded,
    Overloaded,
    NotLeader,
}

impl ErrorCode {
    /// The wire spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "BAD_REQUEST",
            ErrorCode::NotFound => "NOT_FOUND",
            ErrorCode::Unavailable => "UNAVAILABLE",
            ErrorCode::DeadlineExceeded => "DEADLINE_EXCEEDED",
            ErrorCode::Overloaded => "OVERLOADED",
            ErrorCode::NotLeader => "NOT_LEADER",
        }
    }

    /// Inverse of [`ErrorCode::as_str`].
    pub fn parse(s: &str) -> Option<ErrorCode> {
        match s {
            "BAD_REQUEST" => Some(ErrorCode::BadRequest),
            "NOT_FOUND" => Some(ErrorCode::NotFound),
            "UNAVAILABLE" => Some(ErrorCode::Unavailable),
            "DEADLINE_EXCEEDED" => Some(ErrorCode::DeadlineExceeded),
            "OVERLOADED" => Some(ErrorCode::Overloaded),
            "NOT_LEADER" => Some(ErrorCode::NotLeader),
            _ => None,
        }
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A typed protocol failure: code + human-readable message.
#[derive(Debug, Clone, PartialEq)]
pub struct ProtocolError {
    pub code: ErrorCode,
    pub message: String,
}

impl ProtocolError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ProtocolError {
        ProtocolError { code, message: message.into() }
    }

    pub fn bad_request(message: impl Into<String>) -> ProtocolError {
        ProtocolError::new(ErrorCode::BadRequest, message)
    }
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.code, self.message)
    }
}

impl std::error::Error for ProtocolError {}

// ---------- op-object encoders (shared by requests and WAL payloads) ----

/// Borrowing encoders for the op objects. [`Request::to_wire`] and the
/// WAL payload builders both call these, so a mutation's log record is
/// byte-identical to its wire request by construction.
pub mod wire {
    use super::*;

    pub fn insert(point: &Point) -> Json {
        Json::obj(vec![("op", Json::str("insert")), ("point", point.to_json())])
    }

    pub fn delete(id: u64) -> Json {
        Json::obj(vec![("op", Json::str("delete")), ("id", Json::u64(id))])
    }

    pub fn insert_batch(points: &[Point]) -> Json {
        Json::obj(vec![
            ("op", Json::str("insert_batch")),
            ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
        ])
    }

    pub fn delete_batch(ids: &[u64]) -> Json {
        Json::obj(vec![("op", Json::str("delete_batch")), ("ids", Json::u64_arr(ids))])
    }

    pub fn query(point: &Point, k: Option<usize>) -> Json {
        let mut pairs = vec![("op", Json::str("query")), ("point", point.to_json())];
        if let Some(k) = k {
            pairs.push(("k", Json::num(k as f64)));
        }
        Json::obj(pairs)
    }

    pub fn query_id(id: u64, k: Option<usize>) -> Json {
        let mut pairs = vec![("op", Json::str("query_id")), ("id", Json::u64(id))];
        if let Some(k) = k {
            pairs.push(("k", Json::num(k as f64)));
        }
        Json::obj(pairs)
    }

    pub fn query_batch(points: &[Point], k: Option<usize>) -> Json {
        let mut pairs = vec![
            ("op", Json::str("query_batch")),
            ("points", Json::Arr(points.iter().map(|p| p.to_json()).collect())),
        ];
        if let Some(k) = k {
            pairs.push(("k", Json::num(k as f64)));
        }
        Json::obj(pairs)
    }

    pub fn checkpoint() -> Json {
        Json::obj(vec![("op", Json::str("checkpoint"))])
    }

    pub fn stats() -> Json {
        Json::obj(vec![("op", Json::str("stats"))])
    }

    pub fn refresh_tables() -> Json {
        Json::obj(vec![("op", Json::str("refresh_tables"))])
    }

    pub fn wal_subscribe(from_seq: u64) -> Json {
        Json::obj(vec![
            ("op", Json::str("wal_subscribe")),
            ("from_seq", Json::u64(from_seq)),
        ])
    }

    pub fn promote() -> Json {
        Json::obj(vec![("op", Json::str("promote"))])
    }
}

// ---------- requests ----------

/// A decoded RPC request. `k: None` means "use the server's ScaNN-NN
/// default".
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Insert { point: Point },
    Delete { id: u64 },
    Query { point: Point, k: Option<usize> },
    QueryId { id: u64, k: Option<usize> },
    InsertBatch { points: Vec<Point> },
    DeleteBatch { ids: Vec<u64> },
    QueryBatch { points: Vec<Point>, k: Option<usize> },
    Checkpoint,
    Stats,
    /// WAL-internal marker for a periodic table reload (§4.3). Never
    /// accepted from the network; decoded only during WAL replay.
    RefreshTables,
    /// Replication: subscribe to the leader's committed WAL stream
    /// starting at `from_seq` (`0` = "I have nothing, bootstrap me").
    /// Takes over the connection — after the header response the socket
    /// carries raw WAL frames (see docs/REPLICATION.md), so no further
    /// requests are read from it.
    WalSubscribe { from_seq: u64 },
    /// Replication: promote a follower to leader (failover). Answered
    /// with the node's durable WAL seq in the `checkpoint` shape.
    Promote,
}

impl Request {
    /// The wire op name (also the WAL payload op).
    pub fn op_name(&self) -> &'static str {
        match self {
            Request::Insert { .. } => "insert",
            Request::Delete { .. } => "delete",
            Request::Query { .. } => "query",
            Request::QueryId { .. } => "query_id",
            Request::InsertBatch { .. } => "insert_batch",
            Request::DeleteBatch { .. } => "delete_batch",
            Request::QueryBatch { .. } => "query_batch",
            Request::Checkpoint => "checkpoint",
            Request::Stats => "stats",
            Request::RefreshTables => "refresh_tables",
            Request::WalSubscribe { .. } => "wal_subscribe",
            Request::Promote => "promote",
        }
    }

    /// Does this op mutate service state? Mutations on one connection
    /// apply in submission order (the server's ordering guarantee).
    pub fn is_mutation(&self) -> bool {
        matches!(
            self,
            Request::Insert { .. }
                | Request::Delete { .. }
                | Request::InsertBatch { .. }
                | Request::DeleteBatch { .. }
                | Request::RefreshTables
        )
    }

    /// Does this op take a per-connection ordering slot? Mutations, plus
    /// `checkpoint`: a checkpoint pipelined after a mutation on the same
    /// connection must cover that mutation, so it shares the mutation
    /// ordering (queries never do).
    pub fn is_ordered(&self) -> bool {
        self.is_mutation() || matches!(self, Request::Checkpoint)
    }

    /// Encode as the bare op object (the legacy request shape, the
    /// envelope's `req` value, and the WAL payload — all identical).
    /// `update` decodes to [`Request::Insert`] and re-encodes as
    /// `insert`; everything else round-trips exactly.
    pub fn to_wire(&self) -> Json {
        match self {
            Request::Insert { point } => wire::insert(point),
            Request::Delete { id } => wire::delete(*id),
            Request::Query { point, k } => wire::query(point, *k),
            Request::QueryId { id, k } => wire::query_id(*id, *k),
            Request::InsertBatch { points } => wire::insert_batch(points),
            Request::DeleteBatch { ids } => wire::delete_batch(ids),
            Request::QueryBatch { points, k } => wire::query_batch(points, *k),
            Request::Checkpoint => wire::checkpoint(),
            Request::Stats => wire::stats(),
            Request::RefreshTables => wire::refresh_tables(),
            Request::WalSubscribe { from_seq } => wire::wal_subscribe(*from_seq),
            Request::Promote => wire::promote(),
        }
    }

    /// Decode a bare op object (legacy line, envelope `req`, WAL
    /// payload). Field validation — including the `k` bounds — happens
    /// here, before anything touches the service.
    pub fn from_wire(j: &Json) -> Result<Request, ProtocolError> {
        if j.as_obj().is_none() {
            return Err(ProtocolError::bad_request("request must be a JSON object"));
        }
        let op = j
            .get("op")
            .as_str()
            .ok_or_else(|| ProtocolError::bad_request("missing 'op'"))?;
        match op {
            "insert" | "update" => Ok(Request::Insert { point: decode_point(j.get("point"), "point")? }),
            "delete" => Ok(Request::Delete { id: decode_id(j.get("id"), "id")? }),
            "query" => Ok(Request::Query {
                point: decode_point(j.get("point"), "point")?,
                k: decode_k(j)?,
            }),
            "query_id" => Ok(Request::QueryId {
                id: decode_id(j.get("id"), "id")?,
                k: decode_k(j)?,
            }),
            "insert_batch" => Ok(Request::InsertBatch { points: decode_points(j)? }),
            "delete_batch" => {
                let ids = j
                    .get("ids")
                    .as_arr()
                    .ok_or_else(|| ProtocolError::bad_request("missing/bad 'ids'"))?
                    .iter()
                    .map(|x| decode_id(x, "ids"))
                    .collect::<Result<Vec<u64>, ProtocolError>>()?;
                Ok(Request::DeleteBatch { ids })
            }
            "query_batch" => Ok(Request::QueryBatch { points: decode_points(j)?, k: decode_k(j)? }),
            "checkpoint" => Ok(Request::Checkpoint),
            "stats" => Ok(Request::Stats),
            "refresh_tables" => Ok(Request::RefreshTables),
            "wal_subscribe" => Ok(Request::WalSubscribe {
                from_seq: decode_id(j.get("from_seq"), "from_seq")?,
            }),
            "promote" => Ok(Request::Promote),
            other => Err(ProtocolError::bad_request(format!("unknown op '{other}'"))),
        }
    }
}

fn decode_point(j: &Json, field: &str) -> Result<Point, ProtocolError> {
    Point::from_json(j).ok_or_else(|| ProtocolError::bad_request(format!("missing/bad '{field}'")))
}

fn decode_points(j: &Json) -> Result<Vec<Point>, ProtocolError> {
    j.get("points")
        .as_arr()
        .ok_or_else(|| ProtocolError::bad_request("missing/bad 'points'"))?
        .iter()
        .map(|p| {
            Point::from_json(p)
                .ok_or_else(|| ProtocolError::bad_request("bad point in 'points'"))
        })
        .collect()
}

fn decode_id(j: &Json, field: &str) -> Result<u64, ProtocolError> {
    j.as_u64()
        .ok_or_else(|| ProtocolError::bad_request(format!("missing/bad '{field}'")))
}

/// Decode and validate the optional `k` field: absent means "server
/// default"; present must be an integer in `[1, MAX_K]`.
fn decode_k(j: &Json) -> Result<Option<usize>, ProtocolError> {
    let kj = j.get("k");
    if kj.is_null() {
        return Ok(None);
    }
    let k = kj
        .as_usize()
        .ok_or_else(|| ProtocolError::bad_request("'k' must be a non-negative integer"))?;
    if k == 0 {
        return Err(ProtocolError::bad_request("'k' must be >= 1"));
    }
    if k > MAX_K {
        return Err(ProtocolError::bad_request(format!("'k' {k} exceeds maximum {MAX_K}")));
    }
    Ok(Some(k))
}

// ---------- envelope ----------

/// A v1 request envelope: client-chosen correlation `id` (echoed by the
/// response), optional relative deadline in milliseconds (measured from
/// server receipt; `0` is already expired), an optional priority class
/// (`interactive | batch | replication`, see [`crate::admission`]), and
/// the op object. `class: None` (the wire key absent) keeps today's
/// semantics exactly: the request is shed only by the queue-full
/// backstop and never served degraded.
#[derive(Debug, Clone, PartialEq)]
pub struct Envelope {
    pub id: u64,
    pub deadline_ms: Option<u64>,
    pub class: Option<Class>,
    pub request: Request,
}

impl Envelope {
    pub fn to_wire(&self) -> Json {
        envelope_to_wire_classed(self.id, self.deadline_ms, self.class, self.request.to_wire())
    }
}

/// Encode a v1 envelope around an already-encoded op object — the
/// zero-copy submission path for callers that used the borrowing
/// [`wire`] encoders. Emits no `class` key (the pre-admission wire shape,
/// byte-for-byte).
pub fn envelope_to_wire(id: u64, deadline_ms: Option<u64>, req: Json) -> Json {
    envelope_to_wire_classed(id, deadline_ms, None, req)
}

/// [`envelope_to_wire`] with an optional priority class. `None` omits
/// the key entirely, keeping the envelope byte-identical to the
/// pre-admission wire shape ([`Envelope::to_wire`] goes through here).
pub fn envelope_to_wire_classed(
    id: u64,
    deadline_ms: Option<u64>,
    class: Option<Class>,
    req: Json,
) -> Json {
    let mut pairs = vec![("v", Json::u64(VERSION)), ("id", Json::u64(id)), ("req", req)];
    if let Some(d) = deadline_ms {
        pairs.push(("deadline_ms", Json::u64(d)));
    }
    if let Some(c) = class {
        pairs.push(("class", Json::str(c.as_str())));
    }
    Json::obj(pairs)
}

/// One decoded request line: either a v1 envelope or a legacy bare op.
#[derive(Debug, Clone, PartialEq)]
pub enum Incoming {
    V1(Envelope),
    Legacy(Request),
}

/// A request-decode failure. `v1` records whether the line was
/// envelope-shaped (it had a `"v"` key); `id` is the correlation id when
/// the header was readable. The server echoes `id` when present so a
/// pipelined client can match the failure to its request; with no
/// readable id the error is necessarily connection-level and goes out in
/// the legacy (header-less) shape regardless of `v1`.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodeError {
    pub id: Option<u64>,
    pub v1: bool,
    pub error: ProtocolError,
}

impl DecodeError {
    fn legacy(error: ProtocolError) -> DecodeError {
        DecodeError { id: None, v1: false, error }
    }
}

/// Decode one request line in either dialect (the `"v"` key selects v1).
pub fn decode_request(line: &str) -> Result<Incoming, DecodeError> {
    let j = Json::parse(line)
        .map_err(|e| DecodeError::legacy(ProtocolError::bad_request(format!("bad json: {e}"))))?;
    decode_request_json(&j)
}

/// [`decode_request`] over an already-parsed value.
pub fn decode_request_json(j: &Json) -> Result<Incoming, DecodeError> {
    if j.get("v").is_null() {
        return match Request::from_wire(j) {
            Ok(r) => Ok(Incoming::Legacy(r)),
            Err(e) => Err(DecodeError::legacy(e)),
        };
    }
    // v1 envelope. Recover the correlation id even on errors, so the
    // client can match the failure to the request it pipelined.
    let id = j.get("id").as_u64();
    let fail = |id: Option<u64>, error: ProtocolError| DecodeError { id, v1: true, error };
    match j.get("v").as_u64() {
        Some(v) if v == VERSION => {}
        Some(v) => {
            return Err(fail(
                id,
                ProtocolError::bad_request(format!(
                    "unsupported protocol version {v} (this server speaks v{VERSION})"
                )),
            ))
        }
        None => {
            return Err(fail(id, ProtocolError::bad_request("'v' must be an integer")));
        }
    }
    let Some(id) = id else {
        return Err(fail(
            None,
            ProtocolError::bad_request("envelope missing 'id' (u64 correlation id)"),
        ));
    };
    let deadline_ms = match j.get("deadline_ms") {
        Json::Null => None,
        d => Some(d.as_u64().ok_or_else(|| {
            fail(Some(id), ProtocolError::bad_request("'deadline_ms' must be a non-negative integer"))
        })?),
    };
    let class = match j.get("class") {
        Json::Null => None,
        c => {
            let name = c.as_str().ok_or_else(|| {
                fail(Some(id), ProtocolError::bad_request("'class' must be a string"))
            })?;
            Some(Class::parse(name).ok_or_else(|| {
                fail(
                    Some(id),
                    ProtocolError::bad_request(format!(
                        "unknown class '{name}' (expected interactive | batch | replication)"
                    )),
                )
            })?)
        }
    };
    let req = j.get("req");
    if req.is_null() {
        return Err(fail(
            Some(id),
            ProtocolError::bad_request("envelope missing 'req' (the op object)"),
        ));
    }
    let request = Request::from_wire(req).map_err(|e| fail(Some(id), e))?;
    Ok(Incoming::V1(Envelope { id, deadline_ms, class, request }))
}

// ---------- responses ----------

/// A typed RPC response. Success variants map one-to-one onto the ops
/// that produce them; [`Response::Error`] covers every failure.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// `insert` / `delete` ack: did the point exist?
    Existed { existed: bool },
    /// `insert_batch` / `delete_batch` ack, per input position.
    ExistedBatch { existed: Vec<bool> },
    /// `query` / `query_id` neighborhood. `degraded` is `Some(frac)` when
    /// the server answered under a reduced `max_postings` budget (the
    /// applied fraction of the configured budget); `None` encodes with no
    /// extra keys — byte-identical to the pre-admission wire shape.
    Neighbors { neighbors: Vec<ScoredNeighbor>, degraded: Option<f64> },
    /// `query_batch` neighborhoods, per input position. See
    /// [`Response::Neighbors`] for `degraded`.
    Results { results: Vec<Vec<ScoredNeighbor>>, degraded: Option<f64> },
    /// `checkpoint` ack: the WAL sequence number covered.
    Checkpoint { seq: u64 },
    /// `stats` payload.
    Stats { stats: Json },
    /// Any failure. `retry_after_ms` is the admission controller's
    /// backoff hint on `OVERLOADED` sheds; `None` (every other error)
    /// encodes with no extra key.
    Error { code: ErrorCode, message: String, retry_after_ms: Option<u64> },
}

impl Response {
    pub fn error(code: ErrorCode, message: impl Into<String>) -> Response {
        Response::Error { code, message: message.into(), retry_after_ms: None }
    }

    /// An `OVERLOADED` shed carrying the controller's retry hint.
    pub fn overloaded(message: impl Into<String>, retry_after_ms: u64) -> Response {
        Response::Error {
            code: ErrorCode::Overloaded,
            message: message.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    pub fn is_error(&self) -> bool {
        matches!(self, Response::Error { .. })
    }

    /// Encode. `id: None` produces the legacy shape; `Some` adds the v1
    /// header (`v` + echoed `id`). Error responses carry `code` in both
    /// dialects (additive for legacy clients, which only look at
    /// `ok`/`error`).
    pub fn to_wire(&self, id: Option<u64>) -> Json {
        let mut pairs = match self {
            Response::Existed { existed } => {
                vec![("ok", Json::Bool(true)), ("existed", Json::Bool(*existed))]
            }
            Response::ExistedBatch { existed } => vec![
                ("ok", Json::Bool(true)),
                ("existed", Json::Arr(existed.iter().map(|&e| Json::Bool(e)).collect())),
            ],
            Response::Neighbors { neighbors, degraded } => {
                let mut p = vec![("ok", Json::Bool(true)), ("neighbors", neighbors_to_json(neighbors))];
                push_degraded(&mut p, *degraded);
                p
            }
            Response::Results { results, degraded } => {
                let mut p = vec![
                    ("ok", Json::Bool(true)),
                    ("results", Json::Arr(results.iter().map(|r| neighbors_to_json(r)).collect())),
                ];
                push_degraded(&mut p, *degraded);
                p
            }
            Response::Checkpoint { seq } => {
                vec![("ok", Json::Bool(true)), ("seq", Json::u64(*seq))]
            }
            Response::Stats { stats } => {
                vec![("ok", Json::Bool(true)), ("stats", stats.clone())]
            }
            Response::Error { code, message, retry_after_ms } => {
                let mut p = vec![
                    ("ok", Json::Bool(false)),
                    ("code", Json::str(code.as_str())),
                    ("error", Json::str(message.clone())),
                ];
                if let Some(ms) = retry_after_ms {
                    p.push(("retry_after_ms", Json::u64(*ms)));
                }
                p
            }
        };
        if let Some(id) = id {
            pairs.push(("v", Json::u64(VERSION)));
            pairs.push(("id", Json::u64(id)));
        }
        Json::obj(pairs)
    }

    /// Decode a response body. Returns the echoed correlation id (`None`
    /// for legacy / connection-level responses) and the typed response.
    pub fn from_wire(j: &Json) -> Result<(Option<u64>, Response), ProtocolError> {
        if j.as_obj().is_none() {
            return Err(ProtocolError::bad_request("response must be a JSON object"));
        }
        let id = if j.get("v").is_null() { None } else { j.get("id").as_u64() };
        let ok = j
            .get("ok")
            .as_bool()
            .ok_or_else(|| ProtocolError::bad_request("response missing 'ok'"))?;
        if !ok {
            let message = j.get("error").as_str().unwrap_or("<unknown>").to_string();
            let code = j
                .get("code")
                .as_str()
                .and_then(ErrorCode::parse)
                .unwrap_or(ErrorCode::BadRequest);
            let retry_after_ms = j.get("retry_after_ms").as_u64();
            return Ok((id, Response::Error { code, message, retry_after_ms }));
        }
        let degraded = if j.get("degraded").as_bool() == Some(true) {
            Some(j.get("budget_frac").as_f64().unwrap_or(1.0))
        } else {
            None
        };
        let resp = if let Some(b) = j.get("existed").as_bool() {
            Response::Existed { existed: b }
        } else if let Some(arr) = j.get("existed").as_arr() {
            let existed = arr
                .iter()
                .map(|x| {
                    x.as_bool()
                        .ok_or_else(|| ProtocolError::bad_request("bad 'existed' entry"))
                })
                .collect::<Result<Vec<bool>, ProtocolError>>()?;
            Response::ExistedBatch { existed }
        } else if !j.get("neighbors").is_null() {
            Response::Neighbors { neighbors: neighbors_from_json(j.get("neighbors"))?, degraded }
        } else if let Some(arr) = j.get("results").as_arr() {
            let results = arr
                .iter()
                .map(neighbors_from_json)
                .collect::<Result<Vec<_>, ProtocolError>>()?;
            Response::Results { results, degraded }
        } else if let Some(seq) = j.get("seq").as_u64() {
            Response::Checkpoint { seq }
        } else if !j.get("stats").is_null() {
            Response::Stats { stats: j.get("stats").clone() }
        } else {
            return Err(ProtocolError::bad_request("unrecognized response shape"));
        };
        Ok((id, resp))
    }
}

/// Append the degraded-serving marker pair(s) when a budget fraction was
/// applied. `None` appends nothing, keeping non-degraded responses
/// byte-identical to the pre-admission encoding.
fn push_degraded(pairs: &mut Vec<(&'static str, Json)>, degraded: Option<f64>) {
    if let Some(frac) = degraded {
        pairs.push(("degraded", Json::Bool(true)));
        pairs.push(("budget_frac", Json::num(frac)));
    }
}

/// Encode a scored-neighbor list.
pub fn neighbors_to_json(neighbors: &[ScoredNeighbor]) -> Json {
    Json::Arr(
        neighbors
            .iter()
            .map(|n| {
                Json::obj(vec![
                    ("id", Json::u64(n.id)),
                    ("score", Json::num(n.score as f64)),
                    ("dot", Json::num(n.dot as f64)),
                ])
            })
            .collect(),
    )
}

/// Decode a scored-neighbor list. `id` is required; missing scores decode
/// as 0.0 (matching the historical client behavior).
pub fn neighbors_from_json(j: &Json) -> Result<Vec<ScoredNeighbor>, ProtocolError> {
    j.as_arr()
        .ok_or_else(|| ProtocolError::bad_request("missing/bad neighbor list"))?
        .iter()
        .map(|n| {
            Ok(ScoredNeighbor {
                id: n
                    .get("id")
                    .as_u64()
                    .ok_or_else(|| ProtocolError::bad_request("neighbor missing 'id'"))?,
                score: n.get("score").as_f32().unwrap_or(0.0),
                dot: n.get("dot").as_f32().unwrap_or(0.0),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::FeatureValue;

    fn point(id: u64) -> Point {
        Point::new(
            id,
            vec![FeatureValue::Dense(vec![0.5, -1.5]), FeatureValue::Scalar(2021.0)],
        )
    }

    #[test]
    fn request_round_trip_all_variants() {
        let reqs = vec![
            Request::Insert { point: point(1) },
            Request::Delete { id: 42 },
            Request::Query { point: point(2), k: Some(5) },
            Request::Query { point: point(2), k: None },
            Request::QueryId { id: 7, k: Some(3) },
            Request::QueryId { id: 7, k: None },
            Request::InsertBatch { points: vec![point(1), point(2)] },
            Request::DeleteBatch { ids: vec![1, 2, 3] },
            Request::QueryBatch { points: vec![point(9)], k: Some(2) },
            Request::Checkpoint,
            Request::Stats,
            Request::RefreshTables,
            Request::WalSubscribe { from_seq: 0 },
            Request::WalSubscribe { from_seq: 917 },
            Request::Promote,
        ];
        for r in reqs {
            let wire = r.to_wire();
            let back = Request::from_wire(&wire).unwrap();
            assert_eq!(back, r, "{}", wire.dump());
            // Re-encoding is byte-stable.
            assert_eq!(back.to_wire().dump(), wire.dump());
        }
    }

    #[test]
    fn update_aliases_insert() {
        let wire = Json::parse(r#"{"op":"update","point":{"features":[{"scalar":1}],"id":5}}"#)
            .unwrap();
        let r = Request::from_wire(&wire).unwrap();
        assert!(matches!(r, Request::Insert { .. }));
        assert_eq!(r.op_name(), "insert");
    }

    #[test]
    fn k_is_validated_at_decode() {
        for (line, want) in [
            (r#"{"op":"query_id","id":1,"k":0}"#, "'k' must be >= 1"),
            (r#"{"op":"query_id","id":1,"k":9007199254740}"#, "exceeds maximum"),
            (r#"{"op":"query_id","id":1,"k":-3}"#, "non-negative"),
            (r#"{"op":"query_id","id":1,"k":1.5}"#, "non-negative"),
            (r#"{"op":"query_id","id":1,"k":"ten"}"#, "non-negative"),
        ] {
            let j = Json::parse(line).unwrap();
            let err = Request::from_wire(&j).unwrap_err();
            assert_eq!(err.code, ErrorCode::BadRequest, "{line}");
            assert!(err.message.contains(want), "{line}: {}", err.message);
        }
        // Boundary values pass.
        for k in [1usize, MAX_K] {
            let j = Json::parse(&format!(r#"{{"op":"query_id","id":1,"k":{k}}}"#)).unwrap();
            assert_eq!(Request::from_wire(&j).unwrap(), Request::QueryId { id: 1, k: Some(k) });
        }
    }

    #[test]
    fn envelope_round_trip_and_dialect_detection() {
        let env = Envelope {
            id: 7,
            deadline_ms: Some(50),
            class: None,
            request: Request::QueryId { id: 3, k: Some(5) },
        };
        let wire = env.to_wire();
        match decode_request(&wire.dump()).unwrap() {
            Incoming::V1(back) => assert_eq!(back, env),
            other => panic!("not v1: {other:?}"),
        }
        // The same op object, bare, is legacy.
        match decode_request(&env.request.to_wire().dump()).unwrap() {
            Incoming::Legacy(r) => assert_eq!(r, env.request),
            other => panic!("not legacy: {other:?}"),
        }
    }

    #[test]
    fn envelope_class_round_trip() {
        for class in Class::ALL {
            let env = Envelope {
                id: 3,
                deadline_ms: None,
                class: Some(class),
                request: Request::Stats,
            };
            match decode_request(&env.to_wire().dump()).unwrap() {
                Incoming::V1(back) => assert_eq!(back, env),
                other => panic!("not v1: {other:?}"),
            }
        }
        // A class-less envelope encodes byte-identically to the
        // pre-admission shape (no 'class' key on the wire at all).
        let classless = Envelope {
            id: 3,
            deadline_ms: Some(20),
            class: None,
            request: Request::Stats,
        };
        assert_eq!(
            classless.to_wire().dump(),
            envelope_to_wire(3, Some(20), Request::Stats.to_wire()).dump()
        );
        assert!(!classless.to_wire().dump().contains("class"));
        // Bad class values are rejected with the id echoed.
        let e = decode_request(r#"{"v":1,"id":8,"class":"bulk","req":{"op":"stats"}}"#)
            .unwrap_err();
        assert_eq!(e.id, Some(8));
        assert!(e.error.message.contains("unknown class 'bulk'"));
        let e = decode_request(r#"{"v":1,"id":8,"class":3,"req":{"op":"stats"}}"#).unwrap_err();
        assert!(e.error.message.contains("'class' must be a string"));
    }

    #[test]
    fn envelope_header_errors() {
        // Unknown version: error echoes the id and answers in v1 shape.
        let e = decode_request(r#"{"v":2,"id":9,"req":{"op":"stats"}}"#).unwrap_err();
        assert!(e.v1);
        assert_eq!(e.id, Some(9));
        assert!(e.error.message.contains("unsupported protocol version 2"));
        // Missing id.
        let e = decode_request(r#"{"v":1,"req":{"op":"stats"}}"#).unwrap_err();
        assert!(e.v1);
        assert_eq!(e.id, None);
        assert!(e.error.message.contains("missing 'id'"));
        // Missing req.
        let e = decode_request(r#"{"v":1,"id":4}"#).unwrap_err();
        assert_eq!(e.id, Some(4));
        assert!(e.error.message.contains("missing 'req'"));
        // Bad deadline.
        let e = decode_request(r#"{"v":1,"id":4,"deadline_ms":"soon","req":{"op":"stats"}}"#)
            .unwrap_err();
        assert_eq!(e.id, Some(4));
        assert!(e.error.message.contains("deadline_ms"));
        // Bad op inside a valid envelope still echoes the id.
        let e = decode_request(r#"{"v":1,"id":11,"req":{"op":"nope"}}"#).unwrap_err();
        assert!(e.v1);
        assert_eq!(e.id, Some(11));
        assert!(e.error.message.contains("unknown op"));
        // Unparseable json is a legacy-shaped BAD_REQUEST.
        let e = decode_request("{not json").unwrap_err();
        assert!(!e.v1);
        assert_eq!(e.error.code, ErrorCode::BadRequest);
    }

    #[test]
    fn response_round_trip_all_variants() {
        let n = |id, score: f32, dot: f32| ScoredNeighbor { id, score, dot };
        let resps = vec![
            Response::Existed { existed: true },
            Response::ExistedBatch { existed: vec![true, false] },
            Response::Neighbors { neighbors: vec![n(4, 0.5, 3.0), n(9, 0.25, -0.5)], degraded: None },
            Response::Neighbors { neighbors: vec![n(4, 0.5, 3.0)], degraded: Some(0.5) },
            Response::Results { results: vec![vec![n(2, 0.5, 1.0)], vec![]], degraded: None },
            Response::Results { results: vec![vec![n(2, 0.5, 1.0)]], degraded: Some(0.75) },
            Response::Checkpoint { seq: 1041 },
            Response::Stats { stats: Json::obj(vec![("points", Json::num(10.0))]) },
            Response::error(ErrorCode::NotFound, "unknown point 3"),
            Response::error(ErrorCode::Overloaded, "run queue full"),
            Response::overloaded("shed (class=batch)", 120),
        ];
        for r in resps {
            // Legacy shape.
            let (id, back) = Response::from_wire(&r.to_wire(None)).unwrap();
            assert_eq!(id, None);
            assert_eq!(back, r);
            // v1 shape echoes the id.
            let (id, back) = Response::from_wire(&r.to_wire(Some(7))).unwrap();
            assert_eq!(id, Some(7));
            assert_eq!(back, r);
        }
    }

    #[test]
    fn default_path_encodes_without_admission_keys() {
        // Non-degraded / hint-less responses must stay byte-identical to
        // the pre-admission encoding: none of the new keys appear.
        let n = ScoredNeighbor { id: 4, score: 0.5, dot: 3.0 };
        for r in [
            Response::Neighbors { neighbors: vec![n], degraded: None },
            Response::Results { results: vec![vec![n]], degraded: None },
            Response::error(ErrorCode::Overloaded, "run queue full"),
        ] {
            for id in [None, Some(7)] {
                let wire = r.to_wire(id).dump();
                assert!(!wire.contains("degraded"), "{wire}");
                assert!(!wire.contains("budget_frac"), "{wire}");
                assert!(!wire.contains("retry_after_ms"), "{wire}");
            }
        }
        // Degraded marks sit before the v1 header, which stays last.
        let d = Response::Neighbors { neighbors: vec![n], degraded: Some(0.5) };
        let wire = d.to_wire(Some(7)).dump();
        let header = wire.find("\"v\":").unwrap();
        assert!(wire.find("\"degraded\":").unwrap() < header, "{wire}");
    }

    #[test]
    fn error_codes_round_trip() {
        for c in [
            ErrorCode::BadRequest,
            ErrorCode::NotFound,
            ErrorCode::Unavailable,
            ErrorCode::DeadlineExceeded,
            ErrorCode::Overloaded,
            ErrorCode::NotLeader,
        ] {
            assert_eq!(ErrorCode::parse(c.as_str()), Some(c));
        }
        assert_eq!(ErrorCode::parse("TEAPOT"), None);
    }

    #[test]
    fn empty_batches_round_trip() {
        for r in [
            Request::InsertBatch { points: vec![] },
            Request::DeleteBatch { ids: vec![] },
            Request::QueryBatch { points: vec![], k: None },
        ] {
            assert_eq!(Request::from_wire(&r.to_wire()).unwrap(), r);
        }
    }
}
