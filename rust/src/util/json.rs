//! Minimal JSON parser + serializer.
//!
//! The offline environment has no `serde`/`serde_json`, and Dynamic GUS
//! needs JSON in four places: the RPC wire protocol, the trained-model
//! weights exported by the python side (`artifacts/weights_*.json`), config
//! files, and experiment result dumps. This module implements a strict,
//! allocation-conscious JSON subset sufficient for those: all of RFC 8259
//! except `\u` surrogate pairs are fully supported (surrogate pairs are
//! supported too, actually — see `parse_unicode_escape`).
//!
//! Numbers are parsed as f64 (like JavaScript); integer helpers check
//! round-tripping.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Error with byte offset for parse failures.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors -----
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
    pub fn arr(xs: Vec<Json>) -> Json {
        Json::Arr(xs)
    }
    pub fn f32_arr(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    /// u64 array. Values above 2^53 cannot round-trip through f64, so they
    /// are encoded as decimal strings; smaller values stay numbers.
    /// `as_u64`/`to_u64_vec` accept both forms.
    pub fn u64_arr(xs: &[u64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::u64(x)).collect())
    }

    /// A u64 value with full precision (string-encoded when > 2^53).
    pub fn u64(x: u64) -> Json {
        if x <= (1u64 << 53) {
            Json::Num(x as f64)
        } else {
            Json::Str(x.to_string())
        }
    }

    // ----- accessors -----
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_f32(&self) -> Option<f32> {
        self.as_f64().map(|x| x as f32)
    }
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 2f64.powi(53) => {
                Some(*x as u64)
            }
            // Full-precision u64s are string-encoded (see `Json::u64`).
            Json::Str(s) => s.parse().ok(),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && x.abs() <= 2f64.powi(53) => Some(*x as i64),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field access; `Json::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Decode an array of numbers into `Vec<f32>`.
    pub fn to_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f32()?);
        }
        Some(out)
    }

    /// Decode an array of numbers into `Vec<u64>`.
    pub fn to_u64_vec(&self) -> Option<Vec<u64>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_u64()?);
        }
        Some(out)
    }

    // ----- serialization -----
    /// Compact serialization (no whitespace).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

fn write_num(x: f64, out: &mut String) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; weights should never contain them — encode as
        // null so the reader fails loudly rather than silently corrupting.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        // {:?} gives a shortest round-trip representation for f64.
        out.push_str(&format!("{x:?}"));
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => {
                            out.push('"');
                            self.pos += 1;
                        }
                        Some(b'\\') => {
                            out.push('\\');
                            self.pos += 1;
                        }
                        Some(b'/') => {
                            out.push('/');
                            self.pos += 1;
                        }
                        Some(b'n') => {
                            out.push('\n');
                            self.pos += 1;
                        }
                        Some(b't') => {
                            out.push('\t');
                            self.pos += 1;
                        }
                        Some(b'r') => {
                            out.push('\r');
                            self.pos += 1;
                        }
                        Some(b'b') => {
                            out.push('\u{0008}');
                            self.pos += 1;
                        }
                        Some(b'f') => {
                            out.push('\u{000C}');
                            self.pos += 1;
                        }
                        Some(b'u') => {
                            self.pos += 1;
                            let c = self.parse_unicode_escape()?;
                            out.push(c);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // Copy a full UTF-8 char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn parse_unicode_escape(&mut self) -> Result<char, JsonError> {
        let hi = self.hex4()?;
        if (0xD800..0xDC00).contains(&hi) {
            // High surrogate: require a following \uXXXX low surrogate.
            if self.bytes.get(self.pos) == Some(&b'\\')
                && self.bytes.get(self.pos + 1) == Some(&b'u')
            {
                self.pos += 2;
                let lo = self.hex4()?;
                if !(0xDC00..0xE000).contains(&lo) {
                    return Err(self.err("invalid low surrogate"));
                }
                let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                return char::from_u32(c).ok_or_else(|| self.err("invalid surrogate pair"));
            }
            return Err(self.err("lone high surrogate"));
        }
        char::from_u32(hi).ok_or_else(|| self.err("invalid codepoint"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert!(v.get("c").is_null());
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndAé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndAé");
        // Round trip.
        let d = v.dump();
        assert_eq!(Json::parse(&d).unwrap(), v);
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn lone_surrogate_is_error() {
        assert!(Json::parse(r#""\ud83d""#).is_err());
    }

    #[test]
    fn trailing_garbage_is_error() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{} []").is_err());
    }

    #[test]
    fn unterminated_is_error() {
        for bad in ["[1, 2", "{\"a\":", "\"abc", "{\"a\" 1}"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn numbers_roundtrip_f32_vec() {
        let xs = vec![0.0f32, -1.5, 3.25, 1e-7, 12345.678];
        let j = Json::f32_arr(&xs);
        let parsed = Json::parse(&j.dump()).unwrap();
        let ys = parsed.to_f32_vec().unwrap();
        for (a, b) in xs.iter().zip(&ys) {
            assert!((a - b).abs() < 1e-6 * a.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn u64_helpers() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.to_u64_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-7").unwrap().as_i64(), Some(-7));
    }

    #[test]
    fn deterministic_object_order() {
        let v = Json::obj(vec![("b", Json::num(1.0)), ("a", Json::num(2.0))]);
        assert_eq!(v.dump(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
    }
}
