//! A small work-stealing-free thread pool and scoped `parallel_for`.
//!
//! No `rayon`/`tokio` offline, so the offline experiments (Grale full-graph
//! scoring, dataset generation) use this: a fixed pool of workers pulling
//! closures from a shared channel, plus a blocking chunked `parallel_for`
//! built on `std::thread::scope` (no pool needed, no 'static bound).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Jobs are `FnOnce() + Send + 'static`.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `n` workers (min 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("gus-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { sender: Some(tx), workers }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("pool workers gone");
    }

    /// Submit a job and get a handle to its result.
    pub fn submit<T: Send + 'static>(
        &self,
        job: impl FnOnce() -> T + Send + 'static,
    ) -> JobHandle<T> {
        let (tx, rx) = mpsc::channel();
        self.execute(move || {
            // Receiver may have been dropped; ignore send failure.
            let _ = tx.send(job());
        });
        JobHandle { rx }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel, then join workers.
        self.sender.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Handle to a pool job's result.
pub struct JobHandle<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Block until the job finishes.
    pub fn join(self) -> T {
        self.rx.recv().expect("job panicked")
    }
}

/// Default parallelism: number of available cores (capped at 16 to keep the
/// single-machine experiments well-behaved).
pub fn default_parallelism() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

/// Chunked parallel-for over `0..n`: calls `f(chunk_range)` on `threads`
/// scoped threads. `f` only needs to borrow its environment (no 'static).
pub fn parallel_for_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        f(0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo..hi));
        }
    });
}

/// Parallel map over `0..n` producing a `Vec<T>` in index order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = SyncSlice(out.as_mut_ptr() as usize, std::marker::PhantomData::<T>);
        parallel_for_chunks(n, threads, |range| {
            for i in range {
                // SAFETY: each index is written by exactly one chunk/thread.
                unsafe {
                    let ptr = (slots.0 as *mut Option<T>).add(i);
                    std::ptr::write(ptr, Some(f(i)));
                }
            }
        });
    }
    out.into_iter().map(|x| x.expect("all slots written")).collect()
}

// Helper carrying a raw pointer across the Sync boundary.
struct SyncSlice<T>(usize, std::marker::PhantomData<T>);
// SAFETY: the pointer is only ever dereferenced inside `parallel_map`,
// where each index is written by exactly one chunk/thread (chunk ranges
// are disjoint), so shared access never aliases a write.
unsafe impl<T> Sync for SyncSlice<T> {}
impl<T> Clone for SyncSlice<T> {
    fn clone(&self) -> Self {
        SyncSlice(self.0, std::marker::PhantomData)
    }
}
impl<T> Copy for SyncSlice<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            handles.push(pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            }));
        }
        for h in handles {
            h.join();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_returns_values() {
        let pool = ThreadPool::new(2);
        let h1 = pool.submit(|| 21 * 2);
        let h2 = pool.submit(|| "ok".to_string());
        assert_eq!(h1.join(), 42);
        assert_eq!(h2.join(), "ok");
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(3);
        for _ in 0..10 {
            pool.execute(|| {
                std::thread::sleep(std::time::Duration::from_millis(1));
            });
        }
        drop(pool); // must not hang or panic
    }

    #[test]
    fn parallel_for_covers_all_indices() {
        let n = 1000;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, 7, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        // RELAXED: the scope join above orders every fetch_add before
        // these loads; only the per-cell counts matter, not ordering.
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let v = parallel_map(257, 5, |i| i * i);
        assert_eq!(v.len(), 257);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn parallel_map_zero_and_one() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }
}
