//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `program SUBCOMMAND [--flag] [--key=value] [--key value] [pos]`.
//! Typed accessors record which keys were consumed so unknown arguments can
//! be rejected — silent typos in experiment parameters would corrupt results.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    /// First non-flag token (subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut command = None;
        let mut positional = Vec::new();
        let mut options = BTreeMap::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` terminates option parsing.
                    positional.extend(it.by_ref());
                    break;
                }
                let (key, val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => {
                        // `--key value` if next token isn't an option,
                        // otherwise a boolean flag.
                        match it.peek() {
                            Some(next) if !next.starts_with("--") => {
                                (stripped.to_string(), it.next().unwrap())
                            }
                            _ => (stripped.to_string(), "true".to_string()),
                        }
                    }
                };
                if options.insert(key.clone(), val).is_some() {
                    return Err(format!("duplicate option --{key}"));
                }
            } else if command.is_none() {
                command = Some(a);
            } else {
                positional.push(a);
            }
        }
        Ok(Args {
            command,
            positional,
            options,
            consumed: Default::default(),
        })
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Result<Args, String> {
        Args::parse_from(std::env::args().skip(1))
    }

    fn raw(&self, key: &str) -> Option<&str> {
        let v = self.options.get(key).map(|s| s.as_str());
        if v.is_some() {
            self.consumed.borrow_mut().insert(key.to_string());
        }
        v
    }

    /// String option.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.raw(key).unwrap_or(default).to_string()
    }

    /// Optional string option.
    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.raw(key).map(|s| s.to_string())
    }

    /// u64 option with default; panics with a clear message on bad input.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        match self.raw(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")),
        }
    }

    /// usize option.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get_u64(key, default as u64) as usize
    }

    /// f64 option.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        match self.raw(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")),
        }
    }

    /// Boolean flag (`--x`, `--x=true/false`).
    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.raw(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key} expects a boolean, got '{v}'"),
        }
    }

    /// Comma-separated list of u64.
    pub fn get_u64_list(&self, key: &str, default: &[u64]) -> Vec<u64> {
        match self.raw(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{key}: bad integer '{s}'"))
                })
                .collect(),
        }
    }

    /// Error if any provided `--option` was never consumed (catches typos).
    pub fn check_unused(&self) -> Result<(), String> {
        let consumed = self.consumed.borrow();
        let unused: Vec<&String> =
            self.options.keys().filter(|k| !consumed.contains(*k)).collect();
        if unused.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown options: {unused:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse_from(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["serve", "--port=7001", "--dataset", "arxiv", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get_u64("port", 0), 7001);
        assert_eq!(a.get_str("dataset", ""), "arxiv");
        assert!(a.get_bool("verbose", false));
        a.check_unused().unwrap();
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.get_u64("k", 10), 10);
        assert_eq!(a.get_f64("tau", 0.5), 0.5);
        assert!(!a.get_bool("flag", false));
        assert_eq!(a.get_u64_list("nns", &[10, 100]), vec![10, 100]);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["x", "--nns=10,100,1000"]);
        assert_eq!(a.get_u64_list("nns", &[]), vec![10, 100, 1000]);
    }

    #[test]
    fn unused_detection() {
        let a = parse(&["x", "--typo=1"]);
        assert!(a.check_unused().is_err());
        let _ = a.get_u64("typo", 0);
        assert!(a.check_unused().is_ok());
    }

    #[test]
    fn positional_and_dashdash() {
        let a = parse(&["run", "file1", "--", "--not-an-option"]);
        assert_eq!(a.command.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["file1", "--not-an-option"]);
    }

    #[test]
    fn duplicate_option_is_error() {
        assert!(Args::parse_from(["--a=1".to_string(), "--a=2".to_string()]).is_err());
    }

    #[test]
    fn flag_before_value_option() {
        let a = parse(&["x", "--flag", "--k", "5"]);
        assert!(a.get_bool("flag", false));
        assert_eq!(a.get_u64("k", 0), 5);
    }
}
