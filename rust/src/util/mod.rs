//! Hand-rolled utility substrates (the offline build environment has no
//! third-party crates beyond `xla`/`anyhow`/`thiserror`): JSON, RNG and
//! distributions, stable hashing, a thread pool, and CLI parsing.

pub mod cli;
pub mod hash;
pub mod json;
pub mod pool;
pub mod rng;
pub mod threadpool;
