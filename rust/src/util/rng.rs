//! Deterministic pseudo-random number generation and distributions.
//!
//! The offline build environment carries no `rand` crate, so this module
//! implements the pieces the project needs from scratch:
//! - [`Rng`]: xoshiro256++ (Blackman & Vigna), seeded via splitmix64 — fast,
//!   high-quality, and reproducible across platforms;
//! - uniform ints/floats, Box–Muller normals, log-normal, Zipf sampling,
//!   Fisher–Yates shuffle, sampling without replacement.
//!
//! All experiment workloads are generated from explicit seeds so every
//! figure is exactly reproducible.

use crate::util::hash::mix64;

/// xoshiro256++ PRNG with splitmix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    cached_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded with splitmix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            mix64(sm.wrapping_sub(0x9e37_79b9_7f4a_7c15))
        };
        let s = [next(), next(), next(), next()];
        // xoshiro must not be seeded with all zeros; splitmix64 of any seed
        // cannot produce four zeros, but guard anyway.
        let s = if s == [0, 0, 0, 0] { [1, 2, 3, 4] } else { s };
        Rng { s, cached_normal: None }
    }

    /// Derive an independent stream for a sub-task (e.g. per-shard, per-point).
    pub fn fork(&self, stream: u64) -> Rng {
        // Mixing the current state with the stream id gives disjoint streams
        // without advancing `self`.
        Rng::seeded(mix64(self.s[0] ^ mix64(self.s[2] ^ stream)))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire's unbiased bounded sampling).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)` (integer).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (caches the paired variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with underlying normal(mu, sigma).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (rank 0 most likely).
    ///
    /// Uses inverse-CDF on the (approximate) generalized harmonic numbers via
    /// rejection-free discrete inversion over a precomputed table is avoided;
    /// instead we use the standard rejection-inversion method of Hörmann &
    /// Derflinger which needs no table and is O(1) per sample.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        assert!(n >= 1);
        if n == 1 {
            return 0;
        }
        // Rejection-inversion (works for s != 1; nudge s=1 slightly).
        let s = if (s - 1.0).abs() < 1e-9 { 1.0 + 1e-9 } else { s };
        let nf = n as f64;
        let h = |x: f64| -> f64 { ((1.0 + x).powf(1.0 - s) - 1.0) / (1.0 - s) };
        let h_inv = |x: f64| -> f64 { ((1.0 - s) * x + 1.0).powf(1.0 / (1.0 - s)) - 1.0 };
        let h_x1 = h(1.5) - 1.0f64.powf(-s);
        let h_n = h(nf + 0.5);
        loop {
            let u = h_x1 + self.f64() * (h_n - h_x1);
            let x = h_inv(u);
            let k = (x + 0.5).floor().clamp(1.0, nf);
            if k - x <= 0.0 || u >= h(k + 0.5) - k.powf(-s) {
                return k as u64 - 1;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (order unspecified).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        if k * 3 >= n {
            // Dense case: shuffle a full index vector prefix.
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.below_usize(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        } else {
            // Sparse case: rejection with a set.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below_usize(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }

    /// Choose one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }

    /// Vector of iid standard normals as f32.
    pub fn normal_vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seeded(43);
        assert_ne!(Rng::seeded(42).next_u64(), c.next_u64());
    }

    #[test]
    fn fork_streams_are_independent_and_stable() {
        let r = Rng::seeded(7);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(2);
        let mut f1b = r.fork(1);
        assert_eq!(f1.next_u64(), f1b.next_u64());
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::seeded(1);
        let n = 10u64;
        let mut counts = [0u32; 10];
        for _ in 0..10_000 {
            let x = r.below(n);
            assert!(x < n);
            counts[x as usize] += 1;
        }
        for &c in &counts {
            assert!((700..1300).contains(&c), "skewed: {counts:?}");
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::seeded(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((0.48..0.52).contains(&mean), "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(3);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((0.95..1.05).contains(&var), "var={var}");
    }

    #[test]
    fn zipf_rank0_most_frequent() {
        let mut r = Rng::seeded(4);
        let n = 100u64;
        let mut counts = vec![0u32; n as usize];
        for _ in 0..20_000 {
            let k = r.zipf(n, 1.1);
            assert!(k < n);
            counts[k as usize] += 1;
        }
        assert!(counts[0] > counts[9], "{:?}", &counts[..10]);
        assert!(counts[0] > counts[50] * 3);
    }

    #[test]
    fn zipf_n1() {
        let mut r = Rng::seeded(5);
        assert_eq!(r.zipf(1, 1.2), 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seeded(8);
        for &(n, k) in &[(10usize, 10usize), (1000, 10), (50, 25), (5, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::seeded(9);
        for _ in 0..1000 {
            assert!(r.lognormal(1.0, 0.8) > 0.0);
        }
    }
}
