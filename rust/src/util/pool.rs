//! A tiny free-list object pool.
//!
//! Shared by every hot-path scratch type (index query scratches, scorer
//! scratches, coordinator neighbor scratches): `take` never blocks — an
//! empty pool hands out `T::default()` — so the pool's size converges to
//! the peak number of concurrent workers and steady state allocates
//! nothing.

use std::sync::Mutex;

/// Free-list pool of `T`s. `Default` is an empty pool.
#[derive(Debug, Default)]
pub struct Pool<T> {
    items: Mutex<Vec<T>>,
}

impl<T: Default> Pool<T> {
    pub fn new() -> Pool<T> {
        Pool { items: Mutex::new(Vec::new()) }
    }

    /// Pop a pooled item, or a fresh `T::default()` when empty.
    pub fn take(&self) -> T {
        self.items.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return an item to the pool. The caller is responsible for dropping
    /// any payload that should not outlive the call (pools hold returned
    /// items indefinitely).
    pub fn put(&self, item: T) {
        self.items.lock().unwrap().push(item);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_and_falls_back() {
        let pool: Pool<Vec<u8>> = Pool::new();
        let mut v = pool.take();
        assert!(v.is_empty());
        v.reserve(100);
        let cap = v.capacity();
        pool.put(v);
        assert!(pool.take().capacity() >= cap, "pooled item not recycled");
        assert_eq!(pool.take().capacity(), 0, "empty pool must hand out fresh items");
    }

    #[test]
    fn shared_across_threads() {
        let pool: Pool<Vec<u64>> = Pool::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for i in 0..50u64 {
                        let mut v = pool.take();
                        v.push(i);
                        pool.put(v);
                    }
                });
            }
        });
    }
}
