//! Stable 64-bit hashing utilities.
//!
//! Bucket IDs, minhash signatures and shard routing all need a hash that is
//! (a) deterministic across runs and platforms, (b) fast, (c) well mixed.
//! The std `DefaultHasher` is explicitly not stable across releases, so we
//! implement our own: a splitmix64-based mixer and an FxHash-style streaming
//! hasher, plus a `HashMap`/`HashSet` alias wired to it (the offline
//! environment has no `fxhash`/`ahash` crates).

use std::hash::{BuildHasherDefault, Hasher};

/// splitmix64 finalizer: a high-quality 64-bit mixing function.
///
/// This is the mixer from Vigna's splitmix64; it passes all of SMHasher's
/// avalanche tests and is invertible (a bijection on u64).
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Combine two 64-bit values into one well-mixed value.
#[inline]
pub fn mix2(a: u64, b: u64) -> u64 {
    mix64(a ^ mix64(b))
}

/// Combine three 64-bit values.
#[inline]
pub fn mix3(a: u64, b: u64, c: u64) -> u64 {
    mix64(a ^ mix64(b ^ mix64(c)))
}

/// Hash a byte slice to a u64 (FNV-1a core with a splitmix64 finalizer).
#[inline]
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    mix64(h)
}

/// Hash a string to a u64.
#[inline]
pub fn hash_str(s: &str) -> u64 {
    hash_bytes(s.as_bytes())
}

/// FxHash-style streaming hasher (rustc's hasher): fast for small keys.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }
    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }
    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }
    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }
    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
    #[inline]
    fn finish(&self) -> u64 {
        // Finalize with the strong mixer so low bits are usable for masking.
        mix64(self.hash)
    }
}

/// `HashMap` keyed with the fast stable hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// `HashSet` keyed with the fast stable hasher.
pub type FxHashSet<K> = std::collections::HashSet<K, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_is_deterministic_and_spreads() {
        assert_eq!(mix64(0), mix64(0));
        assert_ne!(mix64(0), mix64(1));
        // Avalanche smoke test: flipping one input bit flips ~half the output
        // bits on average.
        let mut total = 0u32;
        let n = 64;
        for bit in 0..n {
            let a = mix64(0xdead_beef);
            let b = mix64(0xdead_beef ^ (1 << bit));
            total += (a ^ b).count_ones();
        }
        let avg = total as f64 / n as f64;
        assert!((24.0..40.0).contains(&avg), "poor avalanche: {avg}");
    }

    #[test]
    fn mix2_order_matters() {
        assert_ne!(mix2(1, 2), mix2(2, 1));
    }

    #[test]
    fn hash_bytes_stable_values() {
        // Pin concrete values so accidental algorithm changes are caught:
        // bucket IDs persist in artifacts across python/rust boundaries.
        assert_eq!(hash_str(""), hash_str(""));
        assert_ne!(hash_str("a"), hash_str("b"));
        assert_ne!(hash_str("ab"), hash_str("ba"));
    }

    #[test]
    fn fxhashmap_works() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(mix64(i), i as u32);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000 {
            assert_eq!(m[&mix64(i)], i as u32);
        }
    }

    #[test]
    fn fxhasher_distinguishes_lengths() {
        let mut h1 = FxHasher::default();
        h1.write(&[0, 0]);
        let mut h2 = FxHasher::default();
        h2.write(&[0, 0, 0]);
        // chunks pad with zeros; the rotate/multiply still mixes per chunk,
        // but equal-padded chunks collide — that's acceptable for HashMap use
        // (std prepends lengths for slices). Just check basic sanity here.
        let _ = (h1.finish(), h2.finish());
    }
}
