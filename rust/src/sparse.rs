//! Sparse vectors over a `u64` dimension space.
//!
//! The embedding `M(p)` of §4.1 has one non-zero dimension per (retained)
//! bucket ID — bucket IDs are 64-bit hashes, so the dimension space is the
//! full `u64` range and a dense representation is impossible. A sparse
//! vector is a sorted list of `(dim, weight)` pairs; the ScaNN-substitute
//! index consumes these directly as posting insertions and computes
//! `Dist(p,q) = -dot(M(p), M(q))`.

use crate::util::json::Json;

/// Immutable sparse vector: dims strictly ascending, weights finite.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SparseVec {
    dims: Vec<u64>,
    weights: Vec<f32>,
}

impl SparseVec {
    /// Empty vector.
    pub fn empty() -> SparseVec {
        SparseVec::default()
    }

    /// Build from unsorted `(dim, weight)` pairs. Duplicate dims are summed
    /// (bucket collisions across channels), zero weights are dropped.
    pub fn from_pairs(mut pairs: Vec<(u64, f32)>) -> SparseVec {
        pairs.sort_unstable_by_key(|&(d, _)| d);
        let mut dims = Vec::with_capacity(pairs.len());
        let mut weights: Vec<f32> = Vec::with_capacity(pairs.len());
        for (d, w) in pairs {
            debug_assert!(w.is_finite(), "non-finite weight for dim {d}");
            if let Some(&last) = dims.last() {
                if last == d {
                    *weights.last_mut().unwrap() += w;
                    continue;
                }
            }
            dims.push(d);
            weights.push(w);
        }
        // Drop zeros created either directly or by cancellation.
        let mut out_d = Vec::with_capacity(dims.len());
        let mut out_w = Vec::with_capacity(dims.len());
        for (d, w) in dims.into_iter().zip(weights) {
            if w != 0.0 {
                out_d.push(d);
                out_w.push(w);
            }
        }
        SparseVec { dims: out_d, weights: out_w }
    }

    /// Number of non-zero dimensions.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.dims.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.dims.is_empty()
    }

    /// Sorted dimensions.
    #[inline]
    pub fn dims(&self) -> &[u64] {
        &self.dims
    }

    /// Weights parallel to `dims()`.
    #[inline]
    pub fn weights(&self) -> &[f32] {
        &self.weights
    }

    /// Iterate `(dim, weight)`.
    pub fn iter(&self) -> impl Iterator<Item = (u64, f32)> + '_ {
        self.dims.iter().copied().zip(self.weights.iter().copied())
    }

    /// Weight of a dimension (0.0 if absent). O(log nnz).
    pub fn get(&self, dim: u64) -> f32 {
        match self.dims.binary_search(&dim) {
            Ok(i) => self.weights[i],
            Err(_) => 0.0,
        }
    }

    /// Dot product via sorted-merge. O(nnz_a + nnz_b).
    pub fn dot(&self, other: &SparseVec) -> f32 {
        let (mut i, mut j) = (0usize, 0usize);
        let mut acc = 0.0f32;
        while i < self.dims.len() && j < other.dims.len() {
            match self.dims[i].cmp(&other.dims[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    acc += self.weights[i] * other.weights[j];
                    i += 1;
                    j += 1;
                }
            }
        }
        acc
    }

    /// Number of shared non-zero dimensions.
    pub fn shared_dims(&self, other: &SparseVec) -> usize {
        let (mut i, mut j, mut n) = (0usize, 0usize, 0usize);
        while i < self.dims.len() && j < other.dims.len() {
            match self.dims[i].cmp(&other.dims[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    n += 1;
                    i += 1;
                    j += 1;
                }
            }
        }
        n
    }

    /// The paper's distance: `Dist(p,q) = -M(p)·M(q)`.
    #[inline]
    pub fn dist(&self, other: &SparseVec) -> f32 {
        -self.dot(other)
    }

    /// Squared L2 norm.
    pub fn norm_sq(&self) -> f32 {
        self.weights.iter().map(|w| w * w).sum()
    }

    /// L2 norm.
    pub fn norm(&self) -> f32 {
        self.norm_sq().sqrt()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dims", Json::u64_arr(&self.dims)),
            ("weights", Json::f32_arr(&self.weights)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<SparseVec> {
        let dims = j.get("dims").to_u64_vec()?;
        let weights = j.get("weights").to_f32_vec()?;
        if dims.len() != weights.len() || dims.windows(2).any(|w| w[0] >= w[1]) {
            return None;
        }
        Some(SparseVec { dims, weights })
    }

    /// Approximate heap size in bytes (for Fig. 10 memory accounting).
    pub fn heap_bytes(&self) -> usize {
        self.dims.capacity() * std::mem::size_of::<u64>()
            + self.weights.capacity() * std::mem::size_of::<f32>()
    }
}

impl FromIterator<(u64, f32)> for SparseVec {
    fn from_iter<T: IntoIterator<Item = (u64, f32)>>(iter: T) -> Self {
        SparseVec::from_pairs(iter.into_iter().collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::*;

    #[test]
    fn from_pairs_sorts_dedups_sums() {
        let v = SparseVec::from_pairs(vec![(5, 1.0), (1, 2.0), (5, 0.5), (3, -1.0)]);
        assert_eq!(v.dims(), &[1, 3, 5]);
        assert_eq!(v.weights(), &[2.0, -1.0, 1.5]);
    }

    #[test]
    fn zeros_dropped() {
        let v = SparseVec::from_pairs(vec![(1, 0.0), (2, 1.0), (3, 0.5), (3, -0.5)]);
        assert_eq!(v.dims(), &[2]);
        assert_eq!(v.nnz(), 1);
    }

    #[test]
    fn dot_matches_manual() {
        let a = SparseVec::from_pairs(vec![(1, 1.0), (2, 2.0), (4, 3.0)]);
        let b = SparseVec::from_pairs(vec![(2, 5.0), (3, 7.0), (4, -1.0)]);
        assert_eq!(a.dot(&b), 2.0 * 5.0 + 3.0 * (-1.0));
        assert_eq!(a.dist(&b), -(a.dot(&b)));
        assert_eq!(a.shared_dims(&b), 2);
    }

    #[test]
    fn dot_empty_is_zero() {
        let a = SparseVec::empty();
        let b = SparseVec::from_pairs(vec![(1, 1.0)]);
        assert_eq!(a.dot(&b), 0.0);
        assert_eq!(a.dot(&a), 0.0);
    }

    #[test]
    fn get_and_norm() {
        let a = SparseVec::from_pairs(vec![(10, 3.0), (20, 4.0)]);
        assert_eq!(a.get(10), 3.0);
        assert_eq!(a.get(15), 0.0);
        assert_eq!(a.norm(), 5.0);
    }

    #[test]
    fn json_roundtrip() {
        let a = SparseVec::from_pairs(vec![(10, 3.5), (20, -4.25), (1 << 60, 1.0)]);
        let j = a.to_json().dump();
        let b = SparseVec::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn from_json_rejects_unsorted() {
        let j = Json::parse(r#"{"dims":[2,1],"weights":[1,1]}"#).unwrap();
        assert!(SparseVec::from_json(&j).is_none());
        let j = Json::parse(r#"{"dims":[1],"weights":[1,2]}"#).unwrap();
        assert!(SparseVec::from_json(&j).is_none());
    }

    /// Property: dot is symmetric and matches a hashmap-based oracle.
    #[test]
    fn prop_dot_symmetric_and_correct() {
        proptest(|rng| {
            let mk = |rng: &mut crate::util::rng::Rng| {
                let n = rng.below_usize(40);
                let pairs: Vec<(u64, f32)> = (0..n)
                    .map(|_| (rng.below(64), rng.f32() * 4.0 - 2.0))
                    .collect();
                SparseVec::from_pairs(pairs)
            };
            let a = mk(rng);
            let b = mk(rng);
            let ab = a.dot(&b);
            let ba = b.dot(&a);
            assert!((ab - ba).abs() < 1e-4, "asymmetric: {ab} vs {ba}");
            // Oracle.
            let mut oracle = 0.0f32;
            for (d, w) in a.iter() {
                oracle += w * b.get(d);
            }
            assert!((ab - oracle).abs() < 1e-3, "dot {ab} vs oracle {oracle}");
        });
    }

    /// Property: shared_dims > 0 ⇔ dot of all-positive vectors > 0
    /// (this is exactly the argument in Lemma 4.1).
    #[test]
    fn prop_lemma41_core() {
        proptest(|rng| {
            let mk = |rng: &mut crate::util::rng::Rng| {
                let n = rng.below_usize(20);
                let pairs: Vec<(u64, f32)> = (0..n)
                    .map(|_| (rng.below(40), 0.01 + rng.f32()))
                    .collect();
                SparseVec::from_pairs(pairs)
            };
            let a = mk(rng);
            let b = mk(rng);
            let share = a.shared_dims(&b) > 0;
            let neg_dist = a.dist(&b) < 0.0;
            assert_eq!(share, neg_dist, "lemma 4.1 violated: share={share}");
        });
    }

    #[test]
    fn prop_norm_triangle() {
        proptest(|rng| {
            let n = rng.below_usize(30);
            let pairs: Vec<(u64, f32)> =
                (0..n).map(|_| (rng.below(50), rng.f32() - 0.5)).collect();
            let a = SparseVec::from_pairs(pairs);
            // Cauchy–Schwarz with itself.
            assert!(a.dot(&a) >= -1e-6);
            assert!((a.dot(&a) - a.norm_sq()).abs() < 1e-4);
        });
    }
}
