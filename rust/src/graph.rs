//! Graph representation and edge-weight distribution tooling.
//!
//! All quality figures in the paper (Figs. 3–8) plot the *edge weight at
//! each percentile of edges ordered by weight*, together with the total
//! number of edges retrieved. Edge sets can be enormous (the paper reports
//! 175,608,580,162 edges for ogbn-products without bucket splitting), so
//! [`WeightHistogram`] accumulates weights into fixed bins with exact
//! totals — O(1) memory in edge count — and reconstructs percentile curves
//! from the bins.
//!
//! [`Graph`] is a small in-memory weighted adjacency structure used by the
//! downstream-application examples (label propagation, clustering).

use crate::features::PointId;
use crate::util::hash::FxHashMap;
use crate::util::json::Json;

/// Streaming histogram over edge weights in `[0, 1]` (model scores are
/// sigmoid outputs; out-of-range values are clamped into the end bins).
#[derive(Debug, Clone)]
pub struct WeightHistogram {
    bins: Vec<u64>,
    total: u64,
    sum: f64,
}

impl WeightHistogram {
    pub const DEFAULT_BINS: usize = 4096;

    pub fn new(n_bins: usize) -> WeightHistogram {
        assert!(n_bins >= 2);
        WeightHistogram { bins: vec![0; n_bins], total: 0, sum: 0.0 }
    }

    pub fn default_bins() -> WeightHistogram {
        WeightHistogram::new(Self::DEFAULT_BINS)
    }

    /// Record one edge weight.
    #[inline]
    pub fn add(&mut self, w: f32) {
        let n = self.bins.len();
        let idx = ((w.clamp(0.0, 1.0) as f64) * n as f64) as usize;
        self.bins[idx.min(n - 1)] += 1;
        self.total += 1;
        self.sum += w as f64;
    }

    /// Record `count` edges of (approximately) equal weight at once.
    pub fn add_many(&mut self, w: f32, count: u64) {
        let n = self.bins.len();
        let idx = (((w.clamp(0.0, 1.0)) as f64) * n as f64) as usize;
        self.bins[idx.min(n - 1)] += count;
        self.total += count;
        self.sum += w as f64 * count as f64;
    }

    pub fn merge(&mut self, other: &WeightHistogram) {
        assert_eq!(self.bins.len(), other.bins.len());
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
    }

    /// Total number of edges recorded.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Mean edge weight.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Weight at percentile `p` ∈ [0, 100] of edges ordered by **ascending**
    /// weight (bin lower edge; max error = bin width).
    pub fn weight_at_percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((p.clamp(0.0, 100.0) / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            acc += c;
            if acc >= target {
                return i as f64 / self.bins.len() as f64;
            }
        }
        1.0
    }

    /// Fraction of edges with weight ≥ `w` (Fig-4-style claims such as
    /// "97% of edges have weight above 0.25").
    pub fn fraction_at_or_above(&self, w: f32) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let n = self.bins.len();
        let idx = (((w.clamp(0.0, 1.0)) as f64) * n as f64) as usize;
        let above: u64 = self.bins[idx.min(n - 1)..].iter().sum();
        above as f64 / self.total as f64
    }

    /// The full percentile curve the paper plots: `(percentile, weight)` at
    /// each requested percentile of edges ordered by weight.
    pub fn percentile_curve(&self, percentiles: &[f64]) -> Vec<(f64, f64)> {
        percentiles
            .iter()
            .map(|&p| (p, self.weight_at_percentile(p)))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let curve = self.percentile_curve(&standard_percentiles());
        Json::obj(vec![
            ("total_edges", Json::u64(self.total)),
            ("mean_weight", Json::num(self.mean())),
            (
                "percentiles",
                Json::Arr(curve.iter().map(|&(p, _)| Json::num(p)).collect()),
            ),
            (
                "weights",
                Json::Arr(curve.iter().map(|&(_, w)| Json::num(w)).collect()),
            ),
        ])
    }
}

/// The percentile grid used in all figure reproductions.
pub fn standard_percentiles() -> Vec<f64> {
    (0..=100).step_by(5).map(|p| p as f64).collect()
}

/// A weighted undirected graph keyed by external point ids.
#[derive(Debug, Default, Clone)]
pub struct Graph {
    adj: FxHashMap<PointId, Vec<(PointId, f32)>>,
    n_edges: usize,
}

impl Graph {
    pub fn new() -> Graph {
        Graph::default()
    }

    /// Add an undirected edge (stored in both endpoint lists).
    pub fn add_edge(&mut self, a: PointId, b: PointId, w: f32) {
        self.adj.entry(a).or_default().push((b, w));
        self.adj.entry(b).or_default().push((a, w));
        self.n_edges += 1;
    }

    /// Ensure a node exists even with no edges.
    pub fn add_node(&mut self, a: PointId) {
        self.adj.entry(a).or_default();
    }

    pub fn n_nodes(&self) -> usize {
        self.adj.len()
    }

    /// Undirected edge count.
    pub fn n_edges(&self) -> usize {
        self.n_edges
    }

    pub fn neighbors(&self, a: PointId) -> &[(PointId, f32)] {
        self.adj.get(&a).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn nodes(&self) -> impl Iterator<Item = PointId> + '_ {
        self.adj.keys().copied()
    }

    /// Keep only each node's top-k heaviest incident edges (the paper's
    /// Top-K post-processing). An edge survives if **either** endpoint
    /// keeps it (the union semantics Grale uses: each point keeps its
    /// best neighbors).
    pub fn top_k_prune(&self, k: usize) -> Graph {
        let mut keep: std::collections::BTreeSet<(PointId, PointId)> = Default::default();
        for (&node, edges) in &self.adj {
            let mut es = edges.clone();
            es.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            for &(nbr, _) in es.iter().take(k) {
                keep.insert((node.min(nbr), node.max(nbr)));
            }
        }
        let mut out = Graph::new();
        for &(a, b) in &keep {
            // Recover the weight from either adjacency list.
            let w = self
                .adj
                .get(&a)
                .and_then(|es| es.iter().find(|(n, _)| *n == b))
                .map(|&(_, w)| w)
                .unwrap_or(0.0);
            out.add_edge(a, b, w);
        }
        for &n in self.adj.keys() {
            out.add_node(n);
        }
        out
    }

    /// Connected components via union-find; returns component id per node.
    pub fn connected_components(&self) -> FxHashMap<PointId, usize> {
        let ids: Vec<PointId> = {
            let mut v: Vec<PointId> = self.adj.keys().copied().collect();
            v.sort_unstable();
            v
        };
        let index: FxHashMap<PointId, usize> =
            ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        let mut parent: Vec<usize> = (0..ids.len()).collect();
        fn find(parent: &mut Vec<usize>, mut x: usize) -> usize {
            while parent[x] != x {
                parent[x] = parent[parent[x]];
                x = parent[x];
            }
            x
        }
        for (&a, edges) in &self.adj {
            for &(b, _) in edges {
                let (ra, rb) = (find(&mut parent, index[&a]), find(&mut parent, index[&b]));
                if ra != rb {
                    parent[ra.max(rb)] = ra.min(rb);
                }
            }
        }
        // Normalize component labels to 0..n_components.
        let mut label: FxHashMap<usize, usize> = FxHashMap::default();
        let mut out = FxHashMap::default();
        for (&id, &i) in &index {
            let root = find(&mut parent, i);
            let next = label.len();
            let l = *label.entry(root).or_insert(next);
            out.insert(id, l);
        }
        out
    }

    /// Weighted label propagation for semi-supervised classification — one
    /// of the paper's headline downstream uses ("Clustering, Label
    /// Propagation, and GNNs"). `labels` seeds some nodes; returns the
    /// hardened labels after `iters` rounds.
    pub fn label_propagation(
        &self,
        labels: &FxHashMap<PointId, u32>,
        iters: usize,
    ) -> FxHashMap<PointId, u32> {
        let mut current: FxHashMap<PointId, u32> = labels.clone();
        let mut nodes: Vec<PointId> = self.adj.keys().copied().collect();
        nodes.sort_unstable();
        for _ in 0..iters {
            let mut next = current.clone();
            for &node in &nodes {
                if labels.contains_key(&node) {
                    continue; // seeds are clamped
                }
                let mut votes: FxHashMap<u32, f32> = FxHashMap::default();
                for &(nbr, w) in self.neighbors(node) {
                    if let Some(&l) = current.get(&nbr) {
                        *votes.entry(l).or_insert(0.0) += w;
                    }
                }
                if let Some((&l, _)) = votes
                    .iter()
                    .max_by(|a, b| a.1.total_cmp(b.1).then(b.0.cmp(a.0)))
                {
                    next.insert(node, l);
                }
            }
            if next == current {
                break;
            }
            current = next;
        }
        current
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = WeightHistogram::new(100);
        // 100 edges with weights 0.005, 0.015, ..., 0.995.
        for i in 0..100 {
            h.add(i as f32 / 100.0 + 0.005);
        }
        assert_eq!(h.total(), 100);
        assert!((h.weight_at_percentile(50.0) - 0.49).abs() < 0.03);
        assert!((h.weight_at_percentile(90.0) - 0.89).abs() < 0.03);
        assert!((h.mean() - 0.5).abs() < 0.01);
    }

    #[test]
    fn histogram_fraction_above() {
        let mut h = WeightHistogram::new(100);
        for _ in 0..75 {
            h.add(0.9);
        }
        for _ in 0..25 {
            h.add(0.1);
        }
        assert!((h.fraction_at_or_above(0.5) - 0.75).abs() < 1e-9);
        assert!((h.fraction_at_or_above(0.05) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_merge_and_clamp() {
        let mut a = WeightHistogram::new(64);
        let mut b = WeightHistogram::new(64);
        a.add(2.0); // clamps to 1.0
        b.add(-1.0); // clamps to 0.0
        b.add_many(0.5, 10);
        a.merge(&b);
        assert_eq!(a.total(), 12);
        assert!(a.weight_at_percentile(1.0) < 0.05);
        assert!(a.weight_at_percentile(100.0) > 0.9);
    }

    #[test]
    fn empty_histogram() {
        let h = WeightHistogram::new(16);
        assert_eq!(h.total(), 0);
        assert_eq!(h.weight_at_percentile(50.0), 0.0);
        assert_eq!(h.fraction_at_or_above(0.5), 0.0);
    }

    #[test]
    fn graph_basics() {
        let mut g = Graph::new();
        g.add_edge(1, 2, 0.9);
        g.add_edge(2, 3, 0.8);
        g.add_node(99);
        assert_eq!(g.n_nodes(), 4);
        assert_eq!(g.n_edges(), 2);
        assert_eq!(g.neighbors(2).len(), 2);
        assert!(g.neighbors(99).is_empty());
    }

    #[test]
    fn top_k_prune_keeps_best() {
        let mut g = Graph::new();
        g.add_edge(1, 2, 0.9);
        g.add_edge(1, 3, 0.5);
        g.add_edge(1, 4, 0.1);
        let pruned = g.top_k_prune(1);
        // Node 1 keeps (1,2); nodes 3 and 4 keep their only edge (to 1):
        // union semantics retains all three... node 3's best is (1,3), node
        // 4's best is (1,4). So all edges survive except none.
        assert_eq!(pruned.n_edges(), 3);
        // With k=1 and a star where leaves have only one edge, the union
        // keeps everything; to see pruning, make leaves prefer elsewhere.
        let mut g2 = Graph::new();
        g2.add_edge(1, 2, 0.9);
        g2.add_edge(1, 3, 0.5);
        g2.add_edge(2, 3, 0.95);
        let p2 = g2.top_k_prune(1);
        // best-of: 1→2(0.9), 2→3(0.95), 3→2(0.95) ⇒ edges {1-2, 2-3}.
        assert_eq!(p2.n_edges(), 2);
        assert_eq!(p2.n_nodes(), 3);
    }

    #[test]
    fn connected_components_split() {
        let mut g = Graph::new();
        g.add_edge(1, 2, 1.0);
        g.add_edge(2, 3, 1.0);
        g.add_edge(10, 11, 1.0);
        g.add_node(100);
        let cc = g.connected_components();
        assert_eq!(cc[&1], cc[&3]);
        assert_eq!(cc[&10], cc[&11]);
        assert_ne!(cc[&1], cc[&10]);
        assert_ne!(cc[&1], cc[&100]);
        let distinct: std::collections::BTreeSet<usize> = cc.values().copied().collect();
        assert_eq!(distinct.len(), 3);
    }

    #[test]
    fn label_propagation_spreads() {
        // Chain 1-2-3-4 with seed labels at the ends.
        let mut g = Graph::new();
        g.add_edge(1, 2, 1.0);
        g.add_edge(2, 3, 1.0);
        g.add_edge(3, 4, 1.0);
        let mut seeds = FxHashMap::default();
        seeds.insert(1u64, 7u32);
        let out = g.label_propagation(&seeds, 10);
        assert_eq!(out[&2], 7);
        assert_eq!(out[&3], 7);
        assert_eq!(out[&4], 7);
    }

    #[test]
    fn label_propagation_weighted_majority() {
        // Node 0 has a weak edge to label-A and two strong to label-B.
        let mut g = Graph::new();
        g.add_edge(0, 1, 0.2);
        g.add_edge(0, 2, 0.6);
        g.add_edge(0, 3, 0.6);
        let mut seeds = FxHashMap::default();
        seeds.insert(1u64, 1u32);
        seeds.insert(2u64, 2u32);
        seeds.insert(3u64, 2u32);
        let out = g.label_propagation(&seeds, 5);
        assert_eq!(out[&0], 2);
    }

    #[test]
    fn nan_edge_weights_do_not_panic() {
        // Regression: `top_k_prune` and `label_propagation` sorted edge
        // weights with `partial_cmp(..).unwrap()`, which panics the moment
        // a NaN weight reaches a comparison (the relu-NaN `inf - inf` bug
        // class fixed in the scorer). Both must survive NaN weights.
        let mut g = Graph::new();
        g.add_edge(1, 2, f32::NAN);
        g.add_edge(1, 3, 0.9);
        g.add_edge(1, 4, 0.5);
        g.add_edge(2, 3, 0.4);
        let pruned = g.top_k_prune(1);
        // Under `total_cmp` NaN sorts above every finite weight, so the
        // NaN edge wins node 1's single slot; the prune must still emit a
        // well-formed graph containing each survivor exactly once.
        assert!(pruned.n_edges() >= 1);
        for n in pruned.nodes() {
            assert!(pruned.neighbors(n).iter().all(|&(m, _)| m != n));
        }
        let mut seeds = FxHashMap::default();
        seeds.insert(3u64, 1u32);
        seeds.insert(4u64, 2u32);
        let out = g.label_propagation(&seeds, 5);
        assert_eq!(out[&3], 1);
        assert_eq!(out[&4], 2);
    }
}
