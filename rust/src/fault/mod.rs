//! Deterministic fault injection: disk faults, network faults, backoff.
//!
//! The paper's serving story is "correct and low-latency while the data
//! evolves continuously" — which in production means serving *through*
//! partial failure, not just restarting after a clean crash. This module
//! is the seeded, replay-deterministic fault layer that drives the
//! durability and replication machinery through exactly those failures:
//!
//! - [`plan`] — the `--fault-plan` / `GUS_FAULT_PLAN` grammar
//!   (`wal_append:enospc@seq=1200;fsync:err@nth=3`): *where* a disk
//!   fault fires, *what* it looks like, and *when*.
//! - [`injector`] — the runtime half of a plan: each WAL writer captures
//!   the process-global [`injector::FaultInjector`] at open time and
//!   consults it at the injection sites in
//!   [`crate::coordinator::wal`] / [`crate::coordinator::snapshot`].
//!   The default (no plan) is a `None` field — one branch on the hot
//!   path, no allocation, no locking.
//! - [`backoff`] — bounded exponential backoff with deterministic seeded
//!   jitter, used by the replication reconnect paths so a dead leader
//!   doesn't make every follower hammer in lockstep.
//! - [`schedule`] — a seeded generator of network-fault windows
//!   (partitions, one-way blackholes, added latency, bandwidth caps,
//!   mid-frame truncation). Same seed ⇒ bit-identical schedule; that is
//!   the replay contract the chaos drill's determinism gate asserts.
//! - [`proxy`] — `gus chaosproxy`: a hand-rolled TCP relay that executes
//!   a [`schedule::Schedule`] between router, followers and leader. The
//!   schedule *executor* necessarily reads the wall clock, so `proxy.rs`
//!   is the one file here exempt from the `replay-determinism` lint.
//!
//! Injected faults and backoff activity are counted in
//! [`crate::metrics::FaultGauges`], surfaced as the `"faults"` stats
//! section — drills assert faults actually fired rather than silently
//! passing. See `docs/CHAOS.md` for the full grammar and the drill's
//! invariant gates.

pub mod backoff;
pub mod injector;
pub mod plan;
pub mod proxy;
pub mod schedule;

pub use backoff::Backoff;
pub use injector::{check_global, global, install_global, FaultInjector};
pub use plan::{FaultKind, FaultPlan, FaultSite, Trigger};
pub use schedule::{NetFault, Schedule, Window};
