//! The fault-plan grammar: which disk fault fires where, and when.
//!
//! A plan is a `;`-separated list of rules, each
//! `site:kind[@trigger]`:
//!
//! ```text
//! wal_append:enospc@seq=1200 ; fsync:err@nth=3 ; checkpoint_rename:crash
//! ```
//!
//! - **site** — `wal_append` (the record write in
//!   `WalWriter::append_frame`), `fsync` (`WalWriter::sync`),
//!   `wal_truncate` (entry of `WalWriter::truncate_retaining` — the
//!   crash-between-checkpoint-commit-and-truncate window), or
//!   `checkpoint_rename` (the `snapshot.json` rename that commits a
//!   checkpoint).
//! - **kind** — `enospc` (a short write then "no space": exercises the
//!   rollback-to-record-boundary path), `err` (a plain I/O error with
//!   nothing written), `crash` (the process aborts at the site, as a real
//!   power cut would — for child-process drills only), or `torn` (a
//!   partial frame hits the disk before the error; `wal_append` only).
//! - **trigger** — `@seq=N` (fire when the record/checkpoint seq is N),
//!   `@nth=N` (fire on the N-th time this site is reached, 1-based), or
//!   omitted (fire every time). `seq`/`nth` rules fire exactly once.
//!
//! Parsing is pure and order-preserving: the same spec always produces
//! the same plan, and [`std::fmt::Display`] round-trips it.

use std::fmt;

use anyhow::{bail, Result};

/// A file-I/O point the WAL/checkpoint path routes through the injector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// The frame write in `WalWriter::append_frame`.
    WalAppend,
    /// `WalWriter::sync` (the fsync the durability policy ordered).
    Fsync,
    /// Entry of `WalWriter::truncate_retaining` — between a committed
    /// checkpoint and the log truncation that depends on it.
    WalTruncate,
    /// The `snapshot.json` rename that commits a checkpoint
    /// (`snapshot::save_with_seq`).
    CheckpointRename,
}

impl FaultSite {
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::WalAppend => "wal_append",
            FaultSite::Fsync => "fsync",
            FaultSite::WalTruncate => "wal_truncate",
            FaultSite::CheckpointRename => "checkpoint_rename",
        }
    }

    fn parse(s: &str) -> Result<FaultSite> {
        Ok(match s {
            "wal_append" => FaultSite::WalAppend,
            "fsync" => FaultSite::Fsync,
            "wal_truncate" => FaultSite::WalTruncate,
            "checkpoint_rename" => FaultSite::CheckpointRename,
            other => bail!(
                "unknown fault site '{other}' \
                 (wal_append|fsync|wal_truncate|checkpoint_rename)"
            ),
        })
    }
}

/// What the injected failure looks like to the caller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// A short write followed by "no space left on device".
    Enospc,
    /// A plain I/O error with nothing written.
    Err,
    /// Abort the process at the site (a power cut, not an error return).
    Crash,
    /// A partial frame reaches the file before the error (`wal_append`
    /// only — models a torn write).
    Torn,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Enospc => "enospc",
            FaultKind::Err => "err",
            FaultKind::Crash => "crash",
            FaultKind::Torn => "torn",
        }
    }

    fn parse(s: &str) -> Result<FaultKind> {
        Ok(match s {
            "enospc" => FaultKind::Enospc,
            "err" => FaultKind::Err,
            "crash" => FaultKind::Crash,
            "torn" => FaultKind::Torn,
            other => bail!("unknown fault kind '{other}' (enospc|err|crash|torn)"),
        })
    }
}

/// When a rule fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trigger {
    /// Every time the site is reached.
    Always,
    /// The N-th time the site is reached (1-based); fires once.
    Nth(u64),
    /// When the seq passed at the site equals N; fires once.
    Seq(u64),
}

impl Trigger {
    fn parse(s: &str) -> Result<Trigger> {
        let Some((key, val)) = s.split_once('=') else {
            bail!("bad fault trigger '{s}' (want seq=N or nth=N)");
        };
        let n: u64 = val
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("bad fault trigger count '{val}'"))?;
        match key.trim() {
            "seq" => Ok(Trigger::Seq(n)),
            "nth" => {
                if n == 0 {
                    bail!("fault trigger nth=0 (counts are 1-based)");
                }
                Ok(Trigger::Nth(n))
            }
            other => bail!("unknown fault trigger '{other}' (seq|nth)"),
        }
    }
}

impl fmt::Display for Trigger {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Trigger::Always => Ok(()),
            Trigger::Nth(n) => write!(f, "@nth={n}"),
            Trigger::Seq(n) => write!(f, "@seq={n}"),
        }
    }
}

/// One `site:kind[@trigger]` rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultRule {
    pub site: FaultSite,
    pub kind: FaultKind,
    pub trigger: Trigger,
}

impl fmt::Display for FaultRule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}{}", self.site.name(), self.kind.name(), self.trigger)
    }
}

/// A parsed fault plan: an ordered list of rules.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultPlan {
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse a spec like `wal_append:enospc@seq=1200;fsync:err@nth=3`.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut rules = Vec::new();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((site, rest)) = part.split_once(':') else {
                bail!("bad fault rule '{part}' (want site:kind[@trigger])");
            };
            let (kind, trigger) = match rest.split_once('@') {
                Some((k, t)) => (k, Trigger::parse(t.trim())?),
                None => (rest, Trigger::Always),
            };
            let rule = FaultRule {
                site: FaultSite::parse(site.trim())?,
                kind: FaultKind::parse(kind.trim())?,
                trigger,
            };
            if rule.kind == FaultKind::Torn && rule.site != FaultSite::WalAppend {
                bail!("fault kind 'torn' only applies to wal_append (got {})", rule);
            }
            rules.push(rule);
        }
        if rules.is_empty() {
            bail!("empty fault plan");
        }
        Ok(FaultPlan { rules })
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, r) in self.rules.iter().enumerate() {
            if i > 0 {
                f.write_str(";")?;
            }
            write!(f, "{r}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_examples() {
        let p = FaultPlan::parse(
            "wal_append:enospc@seq=1200; fsync:err@nth=3 ;checkpoint_rename:crash",
        )
        .unwrap();
        assert_eq!(p.rules.len(), 3);
        assert_eq!(
            p.rules[0],
            FaultRule {
                site: FaultSite::WalAppend,
                kind: FaultKind::Enospc,
                trigger: Trigger::Seq(1200),
            }
        );
        assert_eq!(
            p.rules[1],
            FaultRule {
                site: FaultSite::Fsync,
                kind: FaultKind::Err,
                trigger: Trigger::Nth(3),
            }
        );
        assert_eq!(
            p.rules[2],
            FaultRule {
                site: FaultSite::CheckpointRename,
                kind: FaultKind::Crash,
                trigger: Trigger::Always,
            }
        );
    }

    #[test]
    fn display_round_trips() {
        for spec in [
            "wal_append:enospc@seq=1200",
            "fsync:err@nth=3",
            "checkpoint_rename:crash",
            "wal_truncate:err@nth=1;wal_append:torn@seq=7",
        ] {
            let p = FaultPlan::parse(spec).unwrap();
            assert_eq!(FaultPlan::parse(&p.to_string()).unwrap(), p, "{spec}");
        }
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            " ; ",
            "wal_append",
            "wal_append:explode",
            "nowhere:err",
            "fsync:err@3",
            "fsync:err@nth=zero",
            "fsync:err@nth=0",
            "fsync:err@at=3",
            "fsync:torn",           // torn is wal_append-only
            "wal_truncate:torn@nth=1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }
}
