//! Seeded network-fault schedules: the chaos drill's replay contract.
//!
//! A [`Schedule`] is a list of non-overlapping fault windows over a drill
//! span, generated deterministically from a seed: the same seed always
//! produces the bit-identical schedule (windows, kinds, parameters), so
//! `gus loadgen --chaos <seed>` replays the same fault sequence
//! bit-for-bit. [`Schedule::digest`] hashes the canonical description,
//! giving drills and CI a one-number replay check.
//!
//! Generation leaves the tail of the span fault-free so the cluster has
//! a clean window to reconverge in before the drill's invariant gates
//! run. The schedule *executor* is [`crate::fault::proxy`] — this module
//! stays clock-free (covered by the `replay-determinism` lint).

use crate::util::hash::{hash_bytes, mix2};
use crate::util::rng::Rng;

/// One network fault a chaosproxy can execute. Directions are relative
/// to the proxied client: *up* is client→upstream, *down* is
/// upstream→client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetFault {
    /// Full partition: existing connections are cut, new ones dropped.
    Partition,
    /// One-way blackhole: client→upstream bytes vanish silently.
    BlackholeUp,
    /// One-way blackhole: upstream→client bytes vanish silently.
    BlackholeDown,
    /// Added per-chunk latency, both directions.
    Latency { ms: u64 },
    /// Bandwidth cap, both directions.
    Bandwidth { bytes_per_s: u64 },
    /// Forward half of the next chunk, then cut the connection mid-frame.
    Truncate,
}

impl NetFault {
    pub fn name(self) -> &'static str {
        match self {
            NetFault::Partition => "partition",
            NetFault::BlackholeUp => "blackhole_up",
            NetFault::BlackholeDown => "blackhole_down",
            NetFault::Latency { .. } => "latency",
            NetFault::Bandwidth { .. } => "bandwidth",
            NetFault::Truncate => "truncate",
        }
    }

    fn describe(self) -> String {
        match self {
            NetFault::Latency { ms } => format!("latency({ms}ms)"),
            NetFault::Bandwidth { bytes_per_s } => format!("bandwidth({bytes_per_s}B/s)"),
            other => other.name().to_string(),
        }
    }
}

/// One fault window: `fault` is active for `[start_ms, end_ms)` of
/// elapsed drill time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Window {
    pub start_ms: u64,
    pub end_ms: u64,
    pub fault: NetFault,
}

/// A deterministic, non-overlapping sequence of fault windows.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Schedule {
    pub windows: Vec<Window>,
}

impl Schedule {
    /// A schedule that never injects anything (plain relay).
    pub fn passthrough() -> Schedule {
        Schedule { windows: Vec::new() }
    }

    /// Generate the schedule for one proxy: alternating quiet gaps and
    /// fault windows over `span_ms`, with the last ~fifth of the span
    /// kept fault-free for reconvergence. `ensure_partition` guarantees
    /// at least one partition window (the drill's leader proxy wants one
    /// so the reconnect/backoff machinery is provably exercised); the
    /// rewrite is itself deterministic, so the replay contract holds.
    pub fn generate(seed: u64, span_ms: u64, ensure_partition: bool) -> Schedule {
        let mut rng = Rng::seeded(mix2(seed, 0xc4a0_5eed));
        let tail_quiet = span_ms / 5 + 200;
        let mut windows = Vec::new();
        let mut t = 0u64;
        loop {
            t += 300 + rng.below(900);
            let dur = 400 + rng.below(400);
            if t + dur + tail_quiet > span_ms {
                break;
            }
            let fault = match rng.below(6) {
                0 => NetFault::Partition,
                1 => NetFault::BlackholeUp,
                2 => NetFault::BlackholeDown,
                3 => NetFault::Latency { ms: 20 + rng.below(80) },
                4 => NetFault::Bandwidth { bytes_per_s: 16_384 + rng.below(49_152) },
                _ => NetFault::Truncate,
            };
            windows.push(Window { start_ms: t, end_ms: t + dur, fault });
            t += dur;
        }
        if ensure_partition && !windows.iter().any(|w| w.fault == NetFault::Partition) {
            match windows.first_mut() {
                Some(w) => w.fault = NetFault::Partition,
                None => {
                    // Span too short to have generated anything: synthesize
                    // one early window, still leaving the quiet tail.
                    let start = span_ms / 4;
                    let end = (start + 400).min(span_ms.saturating_sub(tail_quiet)).max(start + 1);
                    windows.push(Window { start_ms: start, end_ms: end, fault: NetFault::Partition });
                }
            }
        }
        Schedule { windows }
    }

    /// The fault active at `elapsed_ms` of drill time, if any.
    pub fn active(&self, elapsed_ms: u64) -> Option<NetFault> {
        self.windows
            .iter()
            .find(|w| w.start_ms <= elapsed_ms && elapsed_ms < w.end_ms)
            .map(|w| w.fault)
    }

    /// Canonical human/machine description, e.g.
    /// `partition@300..800;latency(45ms)@1200..1700`.
    pub fn describe(&self) -> String {
        let parts: Vec<String> = self
            .windows
            .iter()
            .map(|w| format!("{}@{}..{}", w.fault.describe(), w.start_ms, w.end_ms))
            .collect();
        parts.join(";")
    }

    /// Replay digest: a stable hash of the canonical description. Two
    /// schedules are the same iff their digests match (modulo hash
    /// collisions), which is what the drill prints and CI compares.
    pub fn digest(&self) -> u64 {
        hash_bytes(self.describe().as_bytes())
    }

    /// `(kind name, window count)` pairs, in first-seen order.
    pub fn windows_by_kind(&self) -> Vec<(&'static str, u64)> {
        let mut out: Vec<(&'static str, u64)> = Vec::new();
        for w in &self.windows {
            match out.iter_mut().find(|(name, _)| *name == w.fault.name()) {
                Some((_, n)) => *n += 1,
                None => out.push((w.fault.name(), 1)),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_is_bit_identical_different_seed_is_not() {
        let a = Schedule::generate(0xfeed, 10_000, true);
        let b = Schedule::generate(0xfeed, 10_000, true);
        let c = Schedule::generate(0xfeee, 10_000, true);
        assert_eq!(a, b);
        assert_eq!(a.digest(), b.digest());
        assert!(!a.windows.is_empty());
        assert_ne!(a.digest(), c.digest(), "distinct seeds should diverge");
    }

    #[test]
    fn windows_are_ordered_disjoint_and_leave_a_quiet_tail() {
        for seed in 0..50u64 {
            let span = 8_000;
            let sc = Schedule::generate(seed, span, false);
            let mut prev_end = 0;
            for w in &sc.windows {
                assert!(w.start_ms >= prev_end, "overlap at seed {seed}");
                assert!(w.end_ms > w.start_ms);
                assert!(
                    w.end_ms + span / 5 <= span,
                    "seed {seed}: window {}..{} intrudes on the quiet tail",
                    w.start_ms,
                    w.end_ms
                );
                prev_end = w.end_ms;
            }
        }
    }

    #[test]
    fn active_lookup_matches_windows() {
        let sc = Schedule::generate(3, 12_000, false);
        assert!(!sc.windows.is_empty());
        let w = sc.windows[0];
        assert_eq!(sc.active(w.start_ms), Some(w.fault));
        assert_eq!(sc.active(w.end_ms - 1), Some(w.fault));
        assert_eq!(sc.active(w.start_ms.saturating_sub(1)), None);
        assert_eq!(Schedule::passthrough().active(500), None);
    }

    #[test]
    fn ensure_partition_guarantees_one_even_on_short_spans() {
        for seed in 0..50u64 {
            for span in [2_000u64, 6_000] {
                let sc = Schedule::generate(seed, span, true);
                assert!(
                    sc.windows.iter().any(|w| w.fault == NetFault::Partition),
                    "seed {seed} span {span}: no partition window"
                );
            }
        }
    }

    #[test]
    fn digest_tracks_content() {
        let mut sc = Schedule::generate(9, 10_000, false);
        let d0 = sc.digest();
        if let Some(w) = sc.windows.first_mut() {
            w.end_ms += 1;
        }
        assert_ne!(sc.digest(), d0);
        let kinds: u64 = sc.windows_by_kind().iter().map(|&(_, n)| n).sum();
        assert_eq!(kinds as usize, sc.windows.len());
    }
}
