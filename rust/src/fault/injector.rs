//! The runtime half of a fault plan: seeded, exactly-once rule firing.
//!
//! A [`FaultInjector`] is built from a parsed [`FaultPlan`] and consulted
//! at the injection sites in the WAL/checkpoint path via
//! [`FaultInjector::check`]. Each `WalWriter` captures the process-global
//! injector (installed from `--fault-plan` / `GUS_FAULT_PLAN` via
//! [`install_global`]) once at open time, so tests can instead hand a
//! private injector to one writer without any cross-test bleed under
//! parallel `cargo test`.
//!
//! Firing is deterministic: `@nth` rules count visits to their site,
//! `@seq` rules compare the seq the site passes in, and both fire exactly
//! once. Every fired fault is counted in
//! [`crate::metrics::FaultGauges`] so a drill can assert the plan
//! actually executed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Result};

use crate::fault::plan::{FaultKind, FaultPlan, FaultRule, FaultSite, Trigger};

/// One rule plus its firing state.
struct RuleState {
    rule: FaultRule,
    /// Visits to this rule's site (drives `@nth`).
    visits: AtomicU64,
    /// Times this rule has fired (caps `@nth`/`@seq` at one).
    fired: AtomicU64,
}

/// A live fault plan. Cheap to consult: rule lists are tiny and the
/// no-plan case never constructs one at all.
pub struct FaultInjector {
    rules: Vec<RuleState>,
    plan: FaultPlan,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Arc<FaultInjector> {
        let rules = plan
            .rules
            .iter()
            .map(|&rule| RuleState {
                rule,
                visits: AtomicU64::new(0),
                fired: AtomicU64::new(0),
            })
            .collect();
        Arc::new(FaultInjector { rules, plan })
    }

    /// The plan this injector executes (for logging).
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Consult the plan at `site`; `seq` is the record/checkpoint seq the
    /// site is operating on. Returns the fault to inject, if any fires.
    pub fn check(&self, site: FaultSite, seq: u64) -> Option<FaultKind> {
        let mut hit = None;
        for r in &self.rules {
            if r.rule.site != site {
                continue;
            }
            let fires = match r.rule.trigger {
                Trigger::Always => {
                    r.fired.fetch_add(1, Ordering::SeqCst);
                    true
                }
                Trigger::Nth(n) => {
                    let visit = r.visits.fetch_add(1, Ordering::SeqCst) + 1;
                    if visit == n {
                        r.fired.fetch_add(1, Ordering::SeqCst);
                        true
                    } else {
                        false
                    }
                }
                Trigger::Seq(s) => {
                    seq == s
                        && r.fired
                            .compare_exchange(0, 1, Ordering::SeqCst, Ordering::SeqCst)
                            .is_ok()
                }
            };
            if fires {
                hit = hit.or(Some(r.rule.kind));
            }
        }
        if let Some(kind) = hit {
            crate::metrics::faults().note_injected(kind.name());
        }
        hit
    }

    /// Total faults this injector has fired (all rules).
    pub fn fired_total(&self) -> u64 {
        self.rules.iter().map(|r| r.fired.load(Ordering::SeqCst)).sum()
    }
}

/// The process-global injector `--fault-plan` / `GUS_FAULT_PLAN` arms.
static GLOBAL: OnceLock<Arc<FaultInjector>> = OnceLock::new();

/// Arm the process-global fault plan. Fails if one is already armed
/// (plans are process-scoped and never silently replaced).
pub fn install_global(injector: Arc<FaultInjector>) -> Result<()> {
    let plan = injector.plan().to_string();
    if GLOBAL.set(injector).is_err() {
        bail!("a fault plan is already armed in this process (wanted '{plan}')");
    }
    Ok(())
}

/// The armed process-global injector, if any. Captured once per
/// `WalWriter` at open time.
pub fn global() -> Option<Arc<FaultInjector>> {
    GLOBAL.get().cloned()
}

/// Consult the global injector directly (sites without a captured copy).
pub fn check_global(site: FaultSite, seq: u64) -> Option<FaultKind> {
    GLOBAL.get().and_then(|inj| inj.check(site, seq))
}

/// Enact an injected `crash` fault: abort the process at the site, the
/// way a power cut would — no unwinding, no destructors, no flush. Only
/// meaningful for child processes under a drill.
pub fn enact_crash(site: FaultSite) -> ! {
    eprintln!("[fault] injected crash at {}", site.name());
    std::process::abort()
}

/// The error an injected non-crash fault surfaces as. The message
/// carries a stable `injected fault` marker (the server maps it to
/// `UNAVAILABLE`, and tests key on it).
pub fn injected_error(site: FaultSite, kind: FaultKind) -> anyhow::Error {
    let detail = match kind {
        FaultKind::Enospc => "No space left on device (os error 28)",
        FaultKind::Torn => "short write (torn frame)",
        _ => "input/output error",
    };
    anyhow::anyhow!("injected fault at {}: {detail}", site.name())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn injector(spec: &str) -> Arc<FaultInjector> {
        FaultInjector::new(FaultPlan::parse(spec).unwrap())
    }

    #[test]
    fn nth_fires_on_exactly_the_nth_visit() {
        let inj = injector("fsync:err@nth=3");
        assert_eq!(inj.check(FaultSite::Fsync, 0), None);
        assert_eq!(inj.check(FaultSite::Fsync, 0), None);
        assert_eq!(inj.check(FaultSite::Fsync, 0), Some(FaultKind::Err));
        assert_eq!(inj.check(FaultSite::Fsync, 0), None);
        assert_eq!(inj.fired_total(), 1);
    }

    #[test]
    fn seq_fires_once_at_the_target_seq() {
        let inj = injector("wal_append:enospc@seq=5");
        assert_eq!(inj.check(FaultSite::WalAppend, 4), None);
        assert_eq!(inj.check(FaultSite::WalAppend, 5), Some(FaultKind::Enospc));
        // A retry of the same seq succeeds: the rule is spent.
        assert_eq!(inj.check(FaultSite::WalAppend, 5), None);
        assert_eq!(inj.check(FaultSite::WalAppend, 6), None);
    }

    #[test]
    fn always_fires_every_time_and_sites_do_not_cross() {
        let inj = injector("wal_truncate:err");
        for _ in 0..3 {
            assert_eq!(inj.check(FaultSite::WalTruncate, 9), Some(FaultKind::Err));
        }
        assert_eq!(inj.check(FaultSite::WalAppend, 9), None);
        assert_eq!(inj.check(FaultSite::Fsync, 9), None);
        assert_eq!(inj.fired_total(), 3);
    }

    #[test]
    fn visits_only_count_matching_sites() {
        let inj = injector("fsync:err@nth=2;wal_append:err@nth=1");
        assert_eq!(inj.check(FaultSite::WalAppend, 1), Some(FaultKind::Err));
        // The wal_append visit must not have advanced the fsync counter.
        assert_eq!(inj.check(FaultSite::Fsync, 1), None);
        assert_eq!(inj.check(FaultSite::Fsync, 2), Some(FaultKind::Err));
    }

    #[test]
    fn injected_errors_carry_the_marker() {
        let e = injected_error(FaultSite::WalAppend, FaultKind::Enospc);
        let msg = format!("{e}");
        assert!(msg.contains("injected fault"), "{msg}");
        assert!(msg.contains("wal_append"), "{msg}");
        assert!(msg.contains("No space left"), "{msg}");
    }
}
