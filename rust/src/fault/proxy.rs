//! `gus chaosproxy`: a TCP relay that executes a fault [`Schedule`].
//!
//! The proxy sits between cluster members (router → follower, follower →
//! leader) and relays bytes verbatim until its schedule says otherwise:
//! partitions cut existing connections and refuse new ones, one-way
//! blackholes silently swallow bytes in one direction, latency/bandwidth
//! windows shape the relay, and truncate windows cut a connection after
//! forwarding half a chunk (a mid-frame tear on the replication stream).
//!
//! The schedule itself is deterministic from its seed
//! ([`Schedule::generate`]); this module is the *executor* and
//! necessarily reads the wall clock — it is deliberately excluded from
//! the `replay-determinism` lint (see `tools/lint`). The clock starts at
//! [`ChaosProxy::arm`], not at bind time, so a drill can boot its
//! topology through quiescent proxies and start the fault timeline
//! exactly when load starts.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::fault::schedule::{NetFault, Schedule};

/// How long the proxy waits for the upstream when a client connects.
const UPSTREAM_CONNECT_TIMEOUT: Duration = Duration::from_secs(2);

/// Pump read timeout: bounds how stale a pump's view of the schedule can
/// get on an idle connection (a partition must cut idle streams too).
const PUMP_POLL: Duration = Duration::from_millis(100);

/// Relay chunk size. Small enough that latency/bandwidth shaping and
/// truncation act mid-frame on the replication stream.
const CHUNK: usize = 8 * 1024;

/// Relay direction, for one-way faults.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Dir {
    /// client → upstream
    Up,
    /// upstream → client
    Down,
}

struct Shared {
    upstream: String,
    schedule: Schedule,
    /// Fault-timeline origin; `None` = not armed yet (pure passthrough).
    t0: Mutex<Option<Instant>>,
    stop: AtomicBool,
}

impl Shared {
    /// The fault active right now, if the timeline is armed.
    fn active(&self) -> Option<NetFault> {
        let t0 = (*self.t0.lock().unwrap())?;
        self.schedule.active(t0.elapsed().as_millis() as u64)
    }
}

/// A running chaosproxy; dropping it stops the relay.
pub struct ChaosProxy {
    shared: Arc<Shared>,
    addr: String,
}

impl ChaosProxy {
    /// The address the proxy listens on.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Start the fault timeline (before this the proxy is passthrough).
    pub fn arm(&self) {
        *self.shared.t0.lock().unwrap() = Some(Instant::now());
    }

    /// Stop relaying and release the listener.
    pub fn stop(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Nudge the accept loop out of its blocking accept.
        let _ = TcpStream::connect(&self.addr);
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Bind `listen` and relay every connection to `upstream` under
/// `schedule`. Returns immediately; the relay runs on detached threads.
pub fn start(listen: &str, upstream: &str, schedule: Schedule) -> Result<ChaosProxy> {
    let listener = TcpListener::bind(listen).with_context(|| format!("chaosproxy bind {listen}"))?;
    let addr = listener.local_addr()?.to_string();
    let shared = Arc::new(Shared {
        upstream: upstream.to_string(),
        schedule,
        t0: Mutex::new(None),
        stop: AtomicBool::new(false),
    });
    let accept_shared = Arc::clone(&shared);
    std::thread::Builder::new()
        .name("gus-chaosproxy".into())
        .spawn(move || accept_loop(listener, accept_shared))
        .context("spawning chaosproxy accept loop")?;
    Ok(ChaosProxy { shared, addr })
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let Ok(client) = stream else { continue };
        if matches!(shared.active(), Some(NetFault::Partition)) {
            // Partitioned: accept-and-drop looks like a dead host.
            drop(client);
            continue;
        }
        let up = match upstream_connect(&shared.upstream) {
            Ok(s) => s,
            Err(_) => {
                drop(client);
                continue;
            }
        };
        client.set_nodelay(true).ok();
        up.set_nodelay(true).ok();
        spawn_pump(&shared, &client, &up, Dir::Up);
        spawn_pump(&shared, &up, &client, Dir::Down);
    }
}

fn upstream_connect(addr: &str) -> Result<TcpStream> {
    let sock: std::net::SocketAddr = addr.parse().with_context(|| format!("upstream {addr}"))?;
    TcpStream::connect_timeout(&sock, UPSTREAM_CONNECT_TIMEOUT)
        .with_context(|| format!("chaosproxy connect upstream {addr}"))
}

fn spawn_pump(shared: &Arc<Shared>, src: &TcpStream, dst: &TcpStream, dir: Dir) {
    let (Ok(src), Ok(dst)) = (src.try_clone(), dst.try_clone()) else {
        let _ = src.shutdown(Shutdown::Both);
        return;
    };
    let shared = Arc::clone(shared);
    let _ = std::thread::Builder::new()
        .name("gus-chaospump".into())
        .spawn(move || pump(shared, src, dst, dir));
}

/// Relay one direction until the connection dies, the proxy stops, or a
/// partition/truncate window cuts it.
fn pump(shared: Arc<Shared>, mut src: TcpStream, mut dst: TcpStream, dir: Dir) {
    src.set_read_timeout(Some(PUMP_POLL)).ok();
    let mut buf = [0u8; CHUNK];
    loop {
        if shared.stop.load(Ordering::SeqCst)
            || matches!(shared.active(), Some(NetFault::Partition))
        {
            break;
        }
        let n = match src.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                continue;
            }
            Err(_) => break,
        };
        match shared.active() {
            Some(NetFault::Partition) => break,
            Some(NetFault::Truncate) => {
                // Mid-frame tear: half the chunk arrives, then the wire dies.
                let _ = dst.write_all(&buf[..n / 2]);
                break;
            }
            Some(NetFault::BlackholeUp) if dir == Dir::Up => continue,
            Some(NetFault::BlackholeDown) if dir == Dir::Down => continue,
            Some(NetFault::Latency { ms }) => {
                std::thread::sleep(Duration::from_millis(ms));
                if dst.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
            Some(NetFault::Bandwidth { bytes_per_s }) => {
                if dst.write_all(&buf[..n]).is_err() {
                    break;
                }
                let pace_ms = (n as u64 * 1_000) / bytes_per_s.max(1);
                std::thread::sleep(Duration::from_millis(pace_ms));
            }
            _ => {
                if dst.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
        }
    }
    let _ = src.shutdown(Shutdown::Both);
    let _ = dst.shutdown(Shutdown::Both);
}
