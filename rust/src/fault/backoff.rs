//! Bounded exponential backoff with deterministic seeded jitter.
//!
//! The replication reconnect paths used a fixed 1-second pause, which
//! makes every follower (and the router) hammer a dead leader in
//! lockstep. [`Backoff`] replaces that: delays double from a base up to
//! a cap, and each delay is scaled by a jitter factor in `[0.5, 1.0)`
//! drawn from a seeded [`Rng`] — so two nodes seeded differently
//! desynchronize, while the same seed replays the same delay sequence
//! bit-for-bit (the module is covered by the `replay-determinism` lint).
//!
//! The struct is pure: it computes delays, the caller sleeps. Every
//! computed delay counts as a retry in
//! [`crate::metrics::FaultGauges`]; the first time a streak reaches the
//! cap it is counted as a circuit-open window (the remote is considered
//! down, retries are at maximum spacing) until [`Backoff::reset`].

use std::time::Duration;

use crate::util::rng::Rng;

/// Exponential backoff state for one retry loop.
#[derive(Debug)]
pub struct Backoff {
    base_ms: u64,
    cap_ms: u64,
    attempt: u32,
    saturated: bool,
    rng: Rng,
}

impl Backoff {
    /// `base` is the first delay, `cap` the largest (pre-jitter); `seed`
    /// fixes the jitter stream. Seed from something stable and per-node
    /// (an address, a WAL dir) so distinct nodes desynchronize but the
    /// same node replays the same sequence.
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        let base_ms = (base.as_millis() as u64).max(1);
        Backoff {
            base_ms,
            cap_ms: (cap.as_millis() as u64).max(base_ms),
            attempt: 0,
            saturated: false,
            rng: Rng::seeded(seed),
        }
    }

    /// The next delay to sleep before retrying: `min(cap, base << n)`
    /// scaled by a jitter factor in `[0.5, 1.0)`.
    pub fn next_delay(&mut self) -> Duration {
        let shift = self.attempt.min(20);
        let exp_ms = self.cap_ms.min(self.base_ms.saturating_mul(1u64 << shift));
        if exp_ms >= self.cap_ms && !self.saturated {
            self.saturated = true;
            crate::metrics::faults().note_circuit_open();
        }
        self.attempt = self.attempt.saturating_add(1);
        crate::metrics::faults().note_backoff_retry();
        let jitter = 0.5 + 0.5 * self.rng.f64();
        Duration::from_millis(((exp_ms as f64 * jitter) as u64).max(1))
    }

    /// The remote answered: start the next streak from the base delay.
    pub fn reset(&mut self) {
        self.attempt = 0;
        self.saturated = false;
    }

    /// Retries in the current streak.
    pub fn attempt(&self) -> u32 {
        self.attempt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delays(seed: u64, n: usize) -> Vec<Duration> {
        let mut b = Backoff::new(Duration::from_millis(100), Duration::from_secs(5), seed);
        (0..n).map(|_| b.next_delay()).collect()
    }

    #[test]
    fn same_seed_replays_the_same_sequence() {
        assert_eq!(delays(7, 12), delays(7, 12));
        assert_ne!(delays(7, 12), delays(8, 12));
    }

    #[test]
    fn delays_grow_exponentially_within_jitter_bounds() {
        let mut b = Backoff::new(Duration::from_millis(100), Duration::from_secs(5), 42);
        for i in 0..10u32 {
            let exp_ms = 5_000u64.min(100u64 << i.min(20));
            let d = b.next_delay().as_millis() as u64;
            assert!(
                d >= exp_ms / 2 && d <= exp_ms,
                "attempt {i}: delay {d} ms outside [{}, {exp_ms}]",
                exp_ms / 2
            );
        }
    }

    #[test]
    fn cap_bounds_every_delay_and_reset_restarts() {
        let mut b = Backoff::new(Duration::from_millis(200), Duration::from_secs(2), 3);
        for _ in 0..32 {
            assert!(b.next_delay() <= Duration::from_secs(2));
        }
        assert!(b.attempt() >= 32);
        b.reset();
        assert_eq!(b.attempt(), 0);
        // Post-reset the first delay is base-scale again.
        assert!(b.next_delay() <= Duration::from_millis(200));
    }

    #[test]
    fn retries_and_circuit_opens_are_counted() {
        let f = crate::metrics::faults();
        let retries0 = f.backoff_retries();
        let circuits0 = f.circuit_open_windows();
        let mut b = Backoff::new(Duration::from_millis(1), Duration::from_millis(4), 1);
        for _ in 0..8 {
            b.next_delay();
        }
        b.reset();
        for _ in 0..8 {
            b.next_delay();
        }
        assert!(f.backoff_retries() >= retries0 + 16);
        // One circuit-open window per saturated streak.
        assert!(f.circuit_open_windows() >= circuits0 + 2);
    }
}
