//! Load-run reports: latency/staleness quantiles, per-error-code counts,
//! SLO gating, and the `BENCH_index.json` merge.

use std::collections::BTreeMap;

use crate::bench::Bencher;
use crate::loadgen::mix::OP_KINDS;
use crate::loadgen::scenario::SloSpec;
use crate::metrics::LatencySummary;
use crate::util::json::Json;

/// Per-request-kind accounting.
#[derive(Debug, Clone)]
pub struct KindStats {
    pub kind: &'static str,
    pub sent: u64,
    pub ok: u64,
    pub latency: LatencySummary,
}

/// One proxy's executed fault schedule in a chaos drill.
#[derive(Debug, Clone)]
pub struct ChaosProxyReport {
    /// Which link the proxy fronted (`leader`, `follower-1`, …).
    pub label: String,
    /// [`crate::fault::Schedule::digest`] — the replay check number.
    pub digest: u64,
    /// `(fault kind, window count)` pairs.
    pub by_kind: Vec<(&'static str, u64)>,
    /// Canonical schedule description (`partition@300..800;…`).
    pub schedule: String,
}

/// Chaos-drill accounting (`gus loadgen --chaos`); `None` in every
/// other mode.
#[derive(Debug, Clone)]
pub struct ChaosSummary {
    pub seed: u64,
    pub proxies: Vec<ChaosProxyReport>,
    /// Drill end → every follower caught up to the leader's WAL seq
    /// (`None` = the cluster never reconverged, which fails the gate).
    pub reconverge_ms: Option<u64>,
    /// Summed follower/leader `faults.backoff_retries` after the run —
    /// proof the injected faults actually bit the reconnect machinery.
    pub backoff_retries: u64,
}

impl ChaosSummary {
    pub fn to_json(&self) -> Json {
        let proxies = Json::Arr(
            self.proxies
                .iter()
                .map(|p| {
                    let by_kind = Json::Obj(
                        p.by_kind
                            .iter()
                            .map(|&(k, n)| (k.to_string(), Json::u64(n)))
                            .collect(),
                    );
                    Json::obj(vec![
                        ("label", Json::str(p.label.clone())),
                        ("digest", Json::str(format!("{:016x}", p.digest))),
                        ("windows_by_kind", by_kind),
                        ("schedule", Json::str(p.schedule.clone())),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("seed", Json::u64(self.seed)),
            ("proxies", proxies),
            (
                "reconverge_ms",
                self.reconverge_ms.map(Json::u64).unwrap_or(Json::Null),
            ),
            ("backoff_retries", Json::u64(self.backoff_retries)),
        ])
    }
}

/// Everything one load run measured.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Offered (scheduled) arrival rate, req/s.
    pub offered_rate: f64,
    /// Configured send window, seconds.
    pub duration_s: f64,
    /// Measured wall time including the response drain, seconds.
    pub wall_s: f64,
    pub connections: usize,
    pub sent: u64,
    pub ok: u64,
    /// Per-error-code response counts (wire code → count), plus the
    /// pseudo-code `TRANSPORT` for unparseable response lines.
    pub errors: BTreeMap<String, u64>,
    /// Requests submitted but never answered (connection died).
    pub transport_lost: u64,
    /// Successful responses the server marked `degraded` (answered
    /// under a reduced scan budget — see docs/ADMISSION.md).
    pub degraded: u64,
    /// `OVERLOADED` sheds keyed by the request's priority class
    /// (`"unclassed"` when the envelope carried none).
    pub shed_by_class: BTreeMap<String, u64>,
    /// Request latency over every matched response.
    pub latency: LatencySummary,
    pub per_kind: Vec<KindStats>,
    /// Client-observed visible-staleness (mutation submit → ack; the
    /// server applies mutations before acking, so this bounds when the
    /// mutation is query-visible).
    pub staleness_count: u64,
    pub staleness_p50_ms: f64,
    pub staleness_p99_ms: f64,
    /// The server's own `stats` payload at end of run, when reachable.
    pub server_stats: Option<Json>,
    /// Acked mutations whose effect was missing after verification
    /// (`None` = no verification pass ran).
    pub lost_acked_mutations: Option<u64>,
    /// Chaos-drill summary (`gus loadgen --chaos` only).
    pub chaos: Option<ChaosSummary>,
}

impl LoadReport {
    /// Total protocol-level error responses (all codes).
    pub fn error_total(&self) -> u64 {
        self.errors.values().sum()
    }

    /// Acked throughput, req/s over the send window.
    pub fn achieved_rate(&self) -> f64 {
        if self.duration_s > 0.0 {
            self.ok as f64 / self.duration_s
        } else {
            0.0
        }
    }

    /// SLO check: human-readable violations (empty = within SLO).
    /// Latency/staleness only — error and lost-mutation gates are
    /// decided by the caller because their severity is mode-dependent
    /// (a crash run *expects* transport errors).
    pub fn slo_violations(&self, slo: &SloSpec) -> Vec<String> {
        let mut v = Vec::new();
        let p50 = self.latency.p50_ns as f64 / 1e6;
        let p99 = self.latency.p99_ns as f64 / 1e6;
        if p50 > slo.p50_ms {
            v.push(format!("p50 {:.2} ms > SLO {:.2} ms", p50, slo.p50_ms));
        }
        if p99 > slo.p99_ms {
            v.push(format!("p99 {:.2} ms > SLO {:.2} ms", p99, slo.p99_ms));
        }
        if self.staleness_count > 0 && self.staleness_p99_ms > slo.staleness_p99_ms {
            v.push(format!(
                "staleness p99 {:.2} ms > SLO {:.2} ms",
                self.staleness_p99_ms, slo.staleness_p99_ms
            ));
        }
        v
    }

    pub fn to_json(&self) -> Json {
        let errors = Json::Obj(
            self.errors.iter().map(|(k, &v)| (k.clone(), Json::u64(v))).collect(),
        );
        let per_kind = Json::Arr(
            self.per_kind
                .iter()
                .map(|k| {
                    Json::obj(vec![
                        ("kind", Json::str(k.kind)),
                        ("sent", Json::u64(k.sent)),
                        ("ok", Json::u64(k.ok)),
                        ("latency", k.latency.to_json()),
                    ])
                })
                .collect(),
        );
        Json::obj(vec![
            ("offered_rate", Json::num(self.offered_rate)),
            ("achieved_rate", Json::num(self.achieved_rate())),
            ("duration_s", Json::num(self.duration_s)),
            ("wall_s", Json::num(self.wall_s)),
            ("connections", Json::num(self.connections as f64)),
            ("sent", Json::u64(self.sent)),
            ("ok", Json::u64(self.ok)),
            ("errors", errors),
            ("transport_lost", Json::u64(self.transport_lost)),
            ("degraded", Json::u64(self.degraded)),
            (
                "shed_by_class",
                Json::Obj(
                    self.shed_by_class
                        .iter()
                        .map(|(k, &v)| (k.clone(), Json::u64(v)))
                        .collect(),
                ),
            ),
            ("latency", self.latency.to_json()),
            ("per_kind", per_kind),
            (
                "staleness",
                Json::obj(vec![
                    ("count", Json::u64(self.staleness_count)),
                    ("p50_ms", Json::num(self.staleness_p50_ms)),
                    ("p99_ms", Json::num(self.staleness_p99_ms)),
                ]),
            ),
            (
                "server_stats",
                self.server_stats.clone().unwrap_or(Json::Null),
            ),
            (
                "lost_acked_mutations",
                self.lost_acked_mutations.map(Json::u64).unwrap_or(Json::Null),
            ),
            (
                "chaos",
                self.chaos.as_ref().map(ChaosSummary::to_json).unwrap_or(Json::Null),
            ),
        ])
    }

    /// Print the human summary.
    pub fn print(&self) {
        println!(
            "offered {:.0} req/s for {:.1}s on {} connection(s): {} sent, {} ok, {} errors, {} unanswered ({:.0} req/s acked)",
            self.offered_rate,
            self.duration_s,
            self.connections,
            self.sent,
            self.ok,
            self.error_total(),
            self.transport_lost,
            self.achieved_rate(),
        );
        println!(
            "latency: p50 {:.2} ms  p99 {:.2} ms  max {:.2} ms",
            self.latency.p50_ns as f64 / 1e6,
            self.latency.p99_ns as f64 / 1e6,
            self.latency.max_ns as f64 / 1e6
        );
        for k in &self.per_kind {
            if k.sent == 0 {
                continue;
            }
            println!(
                "  {:<12} sent {:>8}  ok {:>8}  p50 {:.2} ms  p99 {:.2} ms",
                k.kind,
                k.sent,
                k.ok,
                k.latency.p50_ns as f64 / 1e6,
                k.latency.p99_ns as f64 / 1e6
            );
        }
        if self.staleness_count > 0 {
            println!(
                "visible staleness (submit→ack): p50 {:.2} ms  p99 {:.2} ms over {} mutations",
                self.staleness_p50_ms, self.staleness_p99_ms, self.staleness_count
            );
        }
        if self.degraded > 0 {
            println!("degraded responses: {} (served under a reduced budget)", self.degraded);
        }
        if !self.shed_by_class.is_empty() {
            println!("overload sheds by class: {:?}", self.shed_by_class);
        }
        if !self.errors.is_empty() {
            println!("error codes: {:?}", self.errors);
        }
        if let Some(chaos) = &self.chaos {
            for p in &chaos.proxies {
                println!(
                    "chaos {:<12} digest {:016x}  {}",
                    p.label,
                    p.digest,
                    if p.schedule.is_empty() { "(passthrough)" } else { &p.schedule }
                );
            }
            match chaos.reconverge_ms {
                Some(ms) => println!(
                    "chaos seed {:#x}: reconverged in {ms} ms, {} backoff retries observed",
                    chaos.seed, chaos.backoff_retries
                ),
                None => println!(
                    "chaos seed {:#x}: cluster did NOT reconverge",
                    chaos.seed
                ),
            }
        }
    }

    /// Merge this run into the repo-root `BENCH_index.json` under the
    /// key `loadgen/<name>` (via the shared [`Bencher`] merge path, so
    /// other targets' cells are preserved). Headline figures are lifted
    /// to top-level entry keys for cheap cross-PR diffing.
    pub fn dump_bench_index(&self, name: &str) {
        let bencher = Bencher::new();
        bencher.dump_repo_summary(
            &format!("loadgen/{name}"),
            vec![
                ("p50_ms".to_string(), Json::num(self.latency.p50_ns as f64 / 1e6)),
                ("p99_ms".to_string(), Json::num(self.latency.p99_ns as f64 / 1e6)),
                ("achieved_rate".to_string(), Json::num(self.achieved_rate())),
                ("staleness_p99_ms".to_string(), Json::num(self.staleness_p99_ms)),
                ("error_total".to_string(), Json::u64(self.error_total())),
                ("degraded".to_string(), Json::u64(self.degraded)),
                ("report".to_string(), self.to_json()),
            ],
        );
    }
}

/// An empty report skeleton the runner fills in (keeps field-order
/// noise out of the runner).
pub fn empty_report(offered_rate: f64, duration_s: f64, connections: usize) -> LoadReport {
    LoadReport {
        offered_rate,
        duration_s,
        wall_s: 0.0,
        connections,
        sent: 0,
        ok: 0,
        errors: BTreeMap::new(),
        transport_lost: 0,
        degraded: 0,
        shed_by_class: BTreeMap::new(),
        latency: zero_summary(),
        per_kind: OP_KINDS
            .iter()
            .map(|k| KindStats { kind: k.name(), sent: 0, ok: 0, latency: zero_summary() })
            .collect(),
        staleness_count: 0,
        staleness_p50_ms: 0.0,
        staleness_p99_ms: 0.0,
        server_stats: None,
        lost_acked_mutations: None,
        chaos: None,
    }
}

fn zero_summary() -> LatencySummary {
    LatencySummary {
        count: 0,
        mean_ns: 0.0,
        p50_ns: 0,
        p90_ns: 0,
        p95_ns: 0,
        p99_ns: 0,
        max_ns: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_with(p50_ms: f64, p99_ms: f64, stale_p99: f64) -> LoadReport {
        let mut r = empty_report(100.0, 2.0, 1);
        r.latency.p50_ns = (p50_ms * 1e6) as u64;
        r.latency.p99_ns = (p99_ms * 1e6) as u64;
        r.staleness_count = 10;
        r.staleness_p99_ms = stale_p99;
        r.sent = 200;
        r.ok = 200;
        r
    }

    #[test]
    fn slo_gate_flags_each_dimension() {
        let slo = SloSpec { p50_ms: 25.0, p99_ms: 100.0, staleness_p99_ms: 1000.0 };
        assert!(report_with(10.0, 50.0, 100.0).slo_violations(&slo).is_empty());
        assert_eq!(report_with(30.0, 50.0, 100.0).slo_violations(&slo).len(), 1);
        assert_eq!(report_with(30.0, 500.0, 2000.0).slo_violations(&slo).len(), 3);
        // No recorded mutations → staleness gate is vacuous.
        let mut r = report_with(1.0, 1.0, 9999.0);
        r.staleness_count = 0;
        assert!(r.slo_violations(&slo).is_empty());
    }

    #[test]
    fn json_report_has_machine_keys() {
        let mut r = report_with(10.0, 50.0, 100.0);
        r.errors.insert("OVERLOADED".into(), 3);
        r.degraded = 5;
        r.shed_by_class.insert("batch".into(), 2);
        r.shed_by_class.insert("interactive".into(), 1);
        let j = r.to_json();
        assert_eq!(j.get("sent").as_u64(), Some(200));
        assert_eq!(j.get("errors").get("OVERLOADED").as_u64(), Some(3));
        assert_eq!(j.get("degraded").as_u64(), Some(5));
        assert_eq!(j.get("shed_by_class").get("batch").as_u64(), Some(2));
        assert_eq!(j.get("shed_by_class").get("interactive").as_u64(), Some(1));
        assert_eq!(j.get("staleness").get("count").as_u64(), Some(10));
        assert!(j.get("lost_acked_mutations").is_null());
        assert_eq!(j.get("achieved_rate").as_f64(), Some(100.0));
        // Round-trips through the serializer.
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn chaos_summary_serializes() {
        let mut r = report_with(1.0, 2.0, 3.0);
        r.chaos = Some(ChaosSummary {
            seed: 7,
            proxies: vec![ChaosProxyReport {
                label: "leader".into(),
                digest: 0xabc,
                by_kind: vec![("partition", 2), ("latency", 1)],
                schedule: "partition@300..800".into(),
            }],
            reconverge_ms: Some(1234),
            backoff_retries: 3,
        });
        let j = r.to_json();
        let chaos = j.get("chaos");
        assert_eq!(chaos.get("seed").as_u64(), Some(7));
        assert_eq!(chaos.get("reconverge_ms").as_u64(), Some(1234));
        assert_eq!(chaos.get("backoff_retries").as_u64(), Some(3));
        let proxies = chaos.get("proxies").as_arr().unwrap();
        assert_eq!(proxies.len(), 1);
        assert_eq!(proxies[0].get("digest").as_str(), Some("0000000000000abc"));
        assert_eq!(
            proxies[0].get("windows_by_kind").get("partition").as_u64(),
            Some(2)
        );
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn achieved_rate_counts_only_acked() {
        let mut r = empty_report(500.0, 4.0, 2);
        r.sent = 2_000;
        r.ok = 1_000;
        assert_eq!(r.achieved_rate(), 250.0);
        assert_eq!(r.error_total(), 0);
    }
}
