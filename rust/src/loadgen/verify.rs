//! Post-run verification: did every acknowledged mutation survive?
//!
//! The runner's [`ConnectionLedger`]s record every mutation in
//! submission order. The server guarantees that, per connection,
//! mutations apply and ack in submission order (queries may overtake
//! mutations, but mutations never reorder against each other). The
//! generator only ever deletes ids *it* inserted on the *same*
//! connection, so the full op history of any fresh id lives on one
//! ledger and is totally ordered.
//!
//! Two subtleties make "assert every acked mutation survived" less
//! trivial than it sounds:
//!
//! 1. **Indeterminate ids.** If an id's trailing ops were submitted but
//!    never acked (the crash window), its final state is genuinely
//!    unknown — the server may or may not have applied them before
//!    dying, and either outcome is correct. Only *determinate* ids
//!    (every op acked) have a forced final state.
//! 2. **Applied prefixes.** Per connection, the recovered state must
//!    correspond to *some* prefix of the submission order that covers at
//!    least the acked ops — durability would also be satisfied by a
//!    longer prefix (ops applied + logged just before the ack was
//!    written). [`find_applied_prefix`] searches for that prefix, which
//!    is what lets a twin service replay the run exactly.

use std::collections::{HashMap, HashSet};

use anyhow::{bail, Context, Result};

use crate::client::GusClient;
use crate::coordinator::DynamicGus;
use crate::loadgen::runner::{ConnectionLedger, MutKind};
use crate::protocol::{ErrorCode, Request, Response};

/// The forced final state of every determinate id: `(id, must_exist)`.
/// `must_exist` is decided by the id's last acked op (insert → present,
/// delete → absent). Ids with any unacked op are skipped — their state
/// is legitimately either way after a crash.
pub fn determinate_final_state(ledgers: &[ConnectionLedger]) -> Vec<(u64, bool)> {
    let mut out = Vec::new();
    for ledger in ledgers {
        // Per-id fold in submission order. Fresh-id spaces are disjoint
        // across connections, so no cross-ledger merging is needed.
        let mut last: HashMap<u64, (bool, bool)> = HashMap::new(); // id -> (all_acked, last_is_insert)
        for r in &ledger.records {
            let e = last.entry(r.id).or_insert((true, false));
            e.0 &= r.acked;
            e.1 = r.kind == MutKind::Insert;
        }
        out.extend(
            last.iter()
                .filter(|(_, (all_acked, _))| *all_acked)
                .map(|(&id, &(_, is_insert))| (id, is_insert)),
        );
    }
    out.sort_unstable();
    out
}

/// Check the determinate final state against an in-process service.
/// Returns the violating `(id, must_exist)` pairs (empty = all good).
pub fn check_survival_inproc(
    gus: &DynamicGus,
    expected: &[(u64, bool)],
) -> Vec<(u64, bool)> {
    expected
        .iter()
        .copied()
        .filter(|&(id, must_exist)| gus.contains(id) != must_exist)
        .collect()
}

/// Check the determinate final state over the wire, by probing
/// `query_id` for each id (pipelined in chunks): a neighbor list means
/// present, a `NOT_FOUND` error response means absent, anything else is
/// a verification failure in its own right.
pub fn check_survival_rpc(
    client: &mut GusClient,
    expected: &[(u64, bool)],
) -> Result<Vec<(u64, bool)>> {
    const CHUNK: usize = 256;
    let mut violations = Vec::new();
    for chunk in expected.chunks(CHUNK) {
        let mut rids = Vec::with_capacity(chunk.len());
        for &(id, _) in chunk {
            rids.push(
                client.submit(Request::QueryId { id, k: Some(1) }).context("probe submit")?,
            );
        }
        for (rid, &(id, must_exist)) in rids.into_iter().zip(chunk) {
            let exists = match client.wait_response(rid).context("probe wait")? {
                Response::Neighbors { .. } => true,
                Response::Error { code: ErrorCode::NotFound, .. } => false,
                other => bail!("probe for id {id} got unexpected response {other:?}"),
            };
            if exists != must_exist {
                violations.push((id, must_exist));
            }
        }
    }
    Ok(violations)
}

/// Find the applied prefix length `m` of one connection's submission
/// order such that applying exactly `records[0..m]` reproduces the
/// recovered presence of every id the ledger touches. Durability
/// requires `m >=` the acked prefix; unacked trailing ops may or may
/// not be included. Returns `None` when no prefix explains the state —
/// i.e. an acked mutation was lost or ops were applied out of order.
///
/// O(records² ) in the worst case — meant for test-scale ledgers.
pub fn find_applied_prefix(
    ledger: &ConnectionLedger,
    applied_contains: impl Fn(u64) -> bool,
) -> Option<usize> {
    // The smallest admissible prefix covers every acked record.
    let min_m = ledger
        .records
        .iter()
        .rposition(|r| r.acked)
        .map(|i| i + 1)
        .unwrap_or(0);
    let touched: HashSet<u64> = ledger.records.iter().map(|r| r.id).collect();

    // Presence after applying records[0..m], grown incrementally.
    let mut present: HashSet<u64> = HashSet::new();
    for r in &ledger.records[..min_m] {
        match r.kind {
            MutKind::Insert => present.insert(r.id),
            MutKind::Delete => present.remove(&r.id),
        };
    }
    for m in min_m..=ledger.records.len() {
        if m > min_m {
            let r = &ledger.records[m - 1];
            match r.kind {
                MutKind::Insert => present.insert(r.id),
                MutKind::Delete => present.remove(&r.id),
            };
        }
        if touched.iter().all(|&id| present.contains(&id) == applied_contains(id)) {
            return Some(m);
        }
    }
    None
}

/// Replay the first `m` records of a ledger into a twin service (the
/// ledger must have been recorded with `record_points`, so inserts carry
/// their points). After this, the twin's state matches the crashed
/// service's recovered state for every id the ledger touches — which is
/// what makes byte-identical query comparison meaningful.
pub fn replay_prefix(gus: &DynamicGus, ledger: &ConnectionLedger, m: usize) -> Result<()> {
    for r in &ledger.records[..m] {
        match r.kind {
            MutKind::Insert => {
                let idx = r
                    .point
                    .context("replay_prefix needs a ledger recorded with record_points")?;
                gus.insert(ledger.points[idx].clone())?;
            }
            MutKind::Delete => {
                gus.delete(r.id)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loadgen::runner::MutationRecord;

    fn rec(kind: MutKind, id: u64, acked: bool) -> MutationRecord {
        MutationRecord { kind, id, acked, point: None }
    }

    fn ledger(records: Vec<MutationRecord>) -> ConnectionLedger {
        ConnectionLedger { records, points: Vec::new() }
    }

    #[test]
    fn determinate_state_follows_last_acked_op() {
        let l = ledger(vec![
            rec(MutKind::Insert, 1, true),
            rec(MutKind::Insert, 2, true),
            rec(MutKind::Delete, 2, true),
            rec(MutKind::Insert, 3, true),
            rec(MutKind::Delete, 3, false), // trailing unacked → id 3 indeterminate
            rec(MutKind::Insert, 4, false), // never acked → indeterminate
        ]);
        let state = determinate_final_state(&[l]);
        assert_eq!(state, vec![(1, true), (2, false)]);
    }

    #[test]
    fn applied_prefix_covers_acked_and_tolerates_unacked_tail() {
        let l = ledger(vec![
            rec(MutKind::Insert, 1, true),
            rec(MutKind::Insert, 2, true),
            rec(MutKind::Insert, 3, false),
            rec(MutKind::Insert, 4, false),
        ]);
        // Recovered state applied 1,2,3 but not 4: a valid prefix (m=3).
        let applied = |id: u64| matches!(id, 1 | 2 | 3);
        assert_eq!(find_applied_prefix(&l, applied), Some(3));
        // Acked-only prefix also valid when nothing extra was applied.
        let acked_only = |id: u64| matches!(id, 1 | 2);
        assert_eq!(find_applied_prefix(&l, acked_only), Some(2));
        // Acked mutation missing → no prefix explains it.
        let lost = |id: u64| id == 2;
        assert_eq!(find_applied_prefix(&l, lost), None);
        // Out-of-order apply (4 without 3) → no prefix explains it.
        let holey = |id: u64| matches!(id, 1 | 2 | 4);
        assert_eq!(find_applied_prefix(&l, holey), None);
    }

    #[test]
    fn applied_prefix_handles_delete_chains() {
        let l = ledger(vec![
            rec(MutKind::Insert, 7, true),
            rec(MutKind::Delete, 7, true),
            rec(MutKind::Insert, 8, false),
        ]);
        // Acked prefix (m=2): 7 absent, 8 absent.
        assert_eq!(find_applied_prefix(&l, |_| false), Some(2));
        // Full prefix (m=3): 8 present.
        assert_eq!(find_applied_prefix(&l, |id| id == 8), Some(3));
        // 7 present contradicts its acked delete.
        assert_eq!(find_applied_prefix(&l, |id| id == 7), None);
    }

    #[test]
    fn empty_ledger_is_trivially_explained() {
        let l = ledger(vec![]);
        assert_eq!(find_applied_prefix(&l, |_| false), Some(0));
        assert!(determinate_final_state(&[l]).is_empty());
    }
}
