//! Replayable load scenarios with SLO thresholds.
//!
//! A [`Scenario`] is a declarative spec — corpus + arrival rate +
//! operation mixture + connection count + SLO thresholds — that the
//! open-loop runner ([`crate::loadgen::runner`]) can replay bit-for-bit
//! from its seeds. Three built-ins promote the `examples/` workloads
//! (android_security, recsys_stream, dynamic_clustering) into specs that
//! `gus loadgen --scenario <name>` drives over the v1 wire protocol, and
//! a fourth (chaos_drill) is the default workload for the network-fault
//! drill (`gus loadgen --chaos`); the
//! [`CorpusSpec`] half is also the shared corpus-setup helper those
//! examples use directly (they used to copy-paste it).

use anyhow::Result;

use crate::config::{GusConfig, ScorerKind};
use crate::data::synthetic::{PointSampler, SyntheticConfig};
use crate::data::Dataset;
use crate::loadgen::mix::Mix;
use crate::util::json::Json;

/// How a scenario's corpus is generated and how the service is
/// configured on top of it. This is the block the three examples each
/// used to spell out by hand.
#[derive(Debug, Clone)]
pub struct CorpusSpec {
    /// `"arxiv_like"` or `"products_like"`.
    pub dataset: String,
    pub n: usize,
    pub seed: u64,
    /// ScaNN-NN retrieval width (`GusConfig::scann_nn`).
    pub k: usize,
    /// Popular-bucket filter threshold (`GusConfig::filter_p`).
    pub filter_p: f64,
    /// IDF smoothing override; `None` keeps the config default.
    pub idf_s: Option<usize>,
}

impl CorpusSpec {
    pub fn new(dataset: &str, n: usize, seed: u64, k: usize) -> CorpusSpec {
        CorpusSpec {
            dataset: dataset.to_string(),
            n,
            seed,
            k,
            filter_p: 10.0,
            idf_s: None,
        }
    }

    /// The generator config for this corpus.
    pub fn synthetic(&self) -> Result<SyntheticConfig> {
        Ok(match self.dataset.as_str() {
            "arxiv_like" => SyntheticConfig::arxiv_like(self.n, self.seed),
            "products_like" => SyntheticConfig::products_like(self.n, self.seed),
            other => anyhow::bail!("unknown dataset '{other}' (arxiv_like|products_like)"),
        })
    }

    /// The service config every scenario/example boots with: retrieval
    /// width `k`, Filter-P on, scorer auto-selected (XLA artifacts if
    /// present, native otherwise).
    pub fn gus_config(&self) -> GusConfig {
        let mut cfg = GusConfig {
            scann_nn: self.k,
            filter_p: self.filter_p,
            scorer: ScorerKind::Auto,
            ..GusConfig::default()
        };
        if let Some(s) = self.idf_s {
            cfg.idf_s = s;
        }
        cfg
    }

    /// Materialize the corpus.
    pub fn generate(&self) -> Result<Dataset> {
        Ok(self.synthetic()?.generate())
    }

    /// Streaming sampler over the same cluster model (for fresh inserts
    /// and query points without materializing the corpus client-side).
    pub fn sampler(&self) -> Result<PointSampler> {
        Ok(self.synthetic()?.sampler())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("n", Json::num(self.n as f64)),
            ("seed", Json::u64(self.seed)),
            ("k", Json::num(self.k as f64)),
            ("filter_p", Json::num(self.filter_p)),
        ])
    }
}

/// SLO thresholds a scenario is gated on at full scale. Latency and
/// staleness gates are advisory by default (`gus loadgen --gate-latency`
/// makes them hard); error/lost-mutation gates are always hard.
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub staleness_p99_ms: f64,
}

impl SloSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
            ("staleness_p99_ms", Json::num(self.staleness_p99_ms)),
        ])
    }
}

/// A replayable load scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub corpus: CorpusSpec,
    /// Offered arrival rate, requests/second across all connections.
    pub rate: f64,
    pub duration_s: f64,
    pub connections: usize,
    pub mix: Mix,
    /// Points per `query_batch` request.
    pub batch: usize,
    /// Per-request deadline attached to every envelope.
    pub deadline_ms: Option<u64>,
    /// Seed for the arrival schedule + op sampling (distinct from the
    /// corpus seed so the same corpus can carry many traffic runs).
    pub load_seed: u64,
    /// Attach priority classes to every request (queries `interactive`,
    /// mutations `batch`) so the server's admission controller can shed
    /// by priority. Off by default: unclassed envelopes are the
    /// pre-admission wire shape, byte for byte.
    pub classes: bool,
    pub slo: SloSpec,
}

/// Names of the built-in scenarios: the promoted `examples/` workloads
/// plus the chaos-drill workload (`gus loadgen --chaos`'s default) and
/// the overload-surge drill workload.
pub const SCENARIO_NAMES: [&str; 5] = [
    "android_security",
    "recsys_stream",
    "dynamic_clustering",
    "chaos_drill",
    "overload_surge",
];

/// Look up a built-in scenario.
///
/// - `android_security` — PHA screening (§1.1): every upload is inserted
///   and immediately neighborhood-scored, so the mixture is
///   mutation-heavy with a query per upload.
/// - `recsys_stream` — "thousands of new entities per second" (§1):
///   listing ingest + shelf queries over many concurrent merchant
///   connections, with batch queries for shelf refreshes.
/// - `dynamic_clustering` — graph mining under churn: query-dominated
///   neighborhood harvesting with a steady trickle of inserts.
/// - `chaos_drill` — the network-fault drill workload: a moderate mixed
///   load (inserts, deletes, queries) long enough for several fault
///   windows plus the reconvergence tail, with per-request deadlines so
///   blackholed requests fail fast instead of wedging a connection.
/// - `overload_surge` — the graceful-degradation drill workload
///   (`gus loadgen --scenario overload_surge` runs the three-phase
///   capacity-probe → surge → recovery drill): a classed mixed load
///   (queries `interactive`, mutations `batch`) driven against a
///   deliberately capacity-constrained server, so priority shedding and
///   degraded-budget serving are what's under test. See docs/ADMISSION.md.
pub fn builtin(name: &str) -> Option<Scenario> {
    let mix = |spec: &str| Mix::parse(spec).expect("builtin mix spec");
    match name {
        "android_security" => Some(Scenario {
            name: name.to_string(),
            corpus: CorpusSpec::new("products_like", 15_000, 0x5ec, 10),
            rate: 400.0,
            duration_s: 30.0,
            connections: 4,
            mix: mix("insert=35,delete=5,query=60"),
            batch: 16,
            deadline_ms: Some(1_000),
            load_seed: 0xbad,
            classes: false,
            slo: SloSpec { p50_ms: 25.0, p99_ms: 150.0, staleness_p99_ms: 1_000.0 },
        }),
        "recsys_stream" => Some(Scenario {
            name: name.to_string(),
            corpus: CorpusSpec::new("products_like", 10_000, 0x0ec, 10),
            rate: 800.0,
            duration_s: 30.0,
            connections: 8,
            mix: mix("insert=40,query=45,query_batch=15"),
            batch: 16,
            deadline_ms: Some(1_000),
            load_seed: 0x0ec5,
            classes: false,
            slo: SloSpec { p50_ms: 25.0, p99_ms: 100.0, staleness_p99_ms: 1_000.0 },
        }),
        "dynamic_clustering" => Some(Scenario {
            name: name.to_string(),
            corpus: CorpusSpec::new("arxiv_like", 8_000, 0xc1, 10),
            rate: 500.0,
            duration_s: 30.0,
            connections: 4,
            mix: mix("insert=13,delete=2,query=85"),
            batch: 16,
            deadline_ms: Some(1_000),
            load_seed: 0x5eed,
            classes: false,
            slo: SloSpec { p50_ms: 25.0, p99_ms: 100.0, staleness_p99_ms: 2_000.0 },
        }),
        "chaos_drill" => Some(Scenario {
            name: name.to_string(),
            corpus: CorpusSpec::new("arxiv_like", 6_000, 0xc405, 10),
            rate: 300.0,
            duration_s: 10.0,
            connections: 4,
            mix: mix("insert=20,delete=5,query=75"),
            batch: 16,
            deadline_ms: Some(1_000),
            load_seed: 0xd311,
            classes: false,
            // Latency under injected partitions/latency windows is not
            // the drill's subject; thresholds stay loose and advisory.
            slo: SloSpec { p50_ms: 100.0, p99_ms: 1_500.0, staleness_p99_ms: 5_000.0 },
        }),
        "overload_surge" => Some(Scenario {
            name: name.to_string(),
            corpus: CorpusSpec::new("arxiv_like", 6_000, 0x0514, 10),
            // The drill's capacity-probe rate; the surge phase offers a
            // multiple of whatever goodput the probe actually measured.
            rate: 1_200.0,
            duration_s: 8.0,
            connections: 4,
            mix: mix("insert=20,delete=5,query=60,query_batch=15"),
            batch: 8,
            deadline_ms: Some(1_000),
            load_seed: 0x0b0d,
            classes: true,
            // The p99 SLO is the bar for *admitted interactive* requests
            // during the surge (the drill gates on the interactive
            // latency histogram, not the overall one).
            slo: SloSpec { p50_ms: 50.0, p99_ms: 250.0, staleness_p99_ms: 2_000.0 },
        }),
        _ => None,
    }
}

impl Scenario {
    /// Shrink to CI/tier-1 smoke scale: toy corpus, sub-second run, SLO
    /// latency thresholds relaxed (smoke gates are "no errors, no lost
    /// mutations, staleness finite" — runner hardware varies too much
    /// for latency gating).
    pub fn smoke(mut self) -> Scenario {
        self.corpus.n = self.corpus.n.min(2_500);
        self.rate = self.rate.min(300.0);
        self.duration_s = 0.8;
        self.connections = self.connections.min(2);
        self.deadline_ms = None;
        self.slo = SloSpec { p50_ms: f64::MAX, p99_ms: f64::MAX, staleness_p99_ms: f64::MAX };
        self
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("corpus", self.corpus.to_json()),
            ("rate", Json::num(self.rate)),
            ("duration_s", Json::num(self.duration_s)),
            ("connections", Json::num(self.connections as f64)),
            ("mix", self.mix.to_json()),
            ("batch", Json::num(self.batch as f64)),
            (
                "deadline_ms",
                self.deadline_ms.map(|d| Json::num(d as f64)).unwrap_or(Json::Null),
            ),
            ("load_seed", Json::u64(self.load_seed)),
            ("classes", Json::Bool(self.classes)),
            ("slo", self.slo.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_and_are_well_formed() {
        for name in SCENARIO_NAMES {
            let sc = builtin(name).unwrap();
            assert_eq!(sc.name, name);
            assert!(sc.rate > 0.0 && sc.duration_s > 0.0 && sc.connections > 0);
            sc.corpus.synthetic().unwrap();
            // Every scenario replays deterministically: spec → json is
            // pure, and corpus/sampler derive from recorded seeds.
            assert_eq!(sc.to_json(), builtin(name).unwrap().to_json());
        }
        assert!(builtin("nope").is_none());
        // The surge drill is the one classed builtin: its whole point is
        // priority-aware shedding.
        assert!(builtin("overload_surge").unwrap().classes);
        assert!(SCENARIO_NAMES.iter().all(|n| {
            let classed = builtin(n).unwrap().classes;
            (*n == "overload_surge") == classed
        }));
    }

    #[test]
    fn smoke_scale_is_tier1_sized() {
        for name in SCENARIO_NAMES {
            let sc = builtin(name).unwrap().smoke();
            assert!(sc.corpus.n <= 5_000, "{name}: smoke corpus too big");
            assert!(sc.duration_s <= 2.0, "{name}: smoke run too long");
        }
    }

    #[test]
    fn corpus_spec_rejects_unknown_dataset() {
        assert!(CorpusSpec::new("mnist", 10, 1, 5).synthetic().is_err());
    }
}
