//! Open-loop load harness + replayable scenario suite.
//!
//! `gus loadgen` drives a live server over the v1 pipelined wire
//! protocol with Poisson arrivals at a configured offered rate — the
//! open-loop discipline where sends are *never* gated on completions,
//! so server slowdowns surface as latency/queueing instead of silently
//! throttling the generator.
//!
//! Module map:
//!
//! - [`mix`] — operation mixtures (`insert=10,delete=2,query=80,...`);
//! - [`scenario`] — replayable declarative workloads with SLO
//!   thresholds; three built-ins promote the `examples/` workloads, a
//!   fourth is the chaos-drill default, and [`scenario::CorpusSpec`] is
//!   the shared corpus-setup helper the examples themselves now use;
//! - [`runner`] — the per-connection writer/reader engine, mutation
//!   ledgers, and staleness recording;
//! - [`report`] — quantiles, per-error-code counts, SLO gating, and the
//!   `BENCH_index.json` merge;
//! - [`verify`] — "no acked mutation lost" proofs: determinate final
//!   state, in-process and over-the-wire survival checks, and
//!   applied-prefix search for crash/recovery twins.
//!
//! See `docs/LOADGEN.md` for the CLI surface and scenario semantics.

pub mod mix;
pub mod report;
pub mod runner;
pub mod scenario;
pub mod verify;

pub use mix::{Mix, OpKind};
pub use report::{ChaosProxyReport, ChaosSummary, LoadReport};
pub use runner::{run_load, ConnectionLedger, LoadOptions, LoadOutcome};
pub use scenario::{builtin, CorpusSpec, Scenario, SloSpec, SCENARIO_NAMES};
