//! The open-loop traffic generator.
//!
//! Requests are dispatched on a fixed arrival schedule (Poisson arrivals
//! at the offered rate) **regardless of completions** — the correct
//! methodology for tail-latency measurement: a slow server does not slow
//! the generator down, it just accumulates in-flight requests, so queue
//! growth and overload shedding show up in the numbers instead of being
//! hidden by generator back-off (closed-loop coordination omission).
//!
//! Each connection runs two threads over one TCP socket speaking
//! protocol v1:
//!
//! - the **writer** sleeps until the next scheduled arrival, samples an
//!   op kind from the [`Mix`], builds the op via the borrowing
//!   `protocol::wire` encoders, and pipelines it out;
//! - the **reader** drains responses (arriving in any order), matches
//!   them to in-flight requests by correlation id, and records latency,
//!   error codes, visible-staleness, and mutation acks.
//!
//! Every mutation is recorded in a per-connection [`ConnectionLedger`]
//! (submission order — which, by the server's per-connection ordering
//! guarantee, is also its apply order), so a verification pass can prove
//! "no acknowledged mutation was lost" after a crash, and a twin service
//! can replay the exact applied prefix.

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::admission::Class;
use crate::client::GusClient;
use crate::coordinator::staleness::StalenessTracker;
use crate::data::synthetic::PointSampler;
use crate::features::Point;
use crate::loadgen::mix::{Mix, OpKind, OP_KINDS};
use crate::loadgen::report::{empty_report, LoadReport};
use crate::loadgen::scenario::Scenario;
use crate::metrics::LatencyHistogram;
use crate::protocol::{self, wire, Response};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Fresh ids minted by the generator start here, far above any corpus
/// id, so generated inserts never collide with corpus points.
pub const FRESH_ID_BASE: u64 = 1 << 40;

/// Fallback ids for deletes drawn while the acked-insert pool is empty
/// (a no-op delete the server still acks). A separate id space keeps the
/// main fresh-id stream — and with it the whole offered workload —
/// deterministic under replay: which inserts have been *acked* by a
/// given arrival depends on server timing, but the ids, points, kinds,
/// and schedule the generator offers must not.
pub const DELETE_FALLBACK_BASE: u64 = 1 << 41;

/// Safety-net read timeout: if a server neither answers nor closes the
/// connection for this long after the send window, the drain gives up
/// and the remaining in-flight requests count as `transport_lost`.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(30);

/// One load run's knobs (the ad-hoc CLI surface; scenarios compile down
/// to this plus a corpus).
#[derive(Debug, Clone)]
pub struct LoadOptions {
    /// Offered arrival rate, requests/second across all connections.
    pub rate: f64,
    /// Send window (the drain afterwards is extra).
    pub duration: Duration,
    pub mix: Mix,
    pub connections: usize,
    /// `k` for query ops.
    pub k: usize,
    /// Points per `query_batch`.
    pub batch: usize,
    pub deadline_ms: Option<u64>,
    /// Arrival-schedule + op-sampling seed (runs are replayable modulo
    /// server timing).
    pub seed: u64,
    /// Keep a clone of every inserted point in the ledger so a twin
    /// service can replay the run (crash tests). Off for pure
    /// throughput runs — it pins every insert in client memory.
    pub record_points: bool,
    /// Attach priority classes (queries `interactive`, mutations
    /// `batch`) so admission control can shed by priority. Off = the
    /// unclassed pre-admission envelope, byte for byte.
    pub classes: bool,
}

impl LoadOptions {
    pub fn from_scenario(sc: &Scenario) -> LoadOptions {
        LoadOptions {
            rate: sc.rate,
            duration: Duration::from_secs_f64(sc.duration_s),
            mix: sc.mix.clone(),
            connections: sc.connections,
            k: sc.corpus.k,
            batch: sc.batch,
            deadline_ms: sc.deadline_ms,
            seed: sc.load_seed,
            record_points: false,
            classes: sc.classes,
        }
    }

    fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.rate > 0.0 && self.rate.is_finite(), "rate must be positive");
        anyhow::ensure!(self.connections > 0, "need at least one connection");
        anyhow::ensure!(self.batch > 0, "batch must be positive");
        anyhow::ensure!(self.k > 0, "k must be positive");
        Ok(())
    }
}

/// A mutation the generator submitted, in submission order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MutKind {
    Insert,
    Delete,
}

#[derive(Debug, Clone)]
pub struct MutationRecord {
    pub kind: MutKind,
    /// The point id the mutation targets.
    pub id: u64,
    /// Did a success response come back?
    pub acked: bool,
    /// Index into [`ConnectionLedger::points`] when `record_points`.
    pub point: Option<usize>,
}

/// Submission-ordered mutation log of one connection.
#[derive(Debug, Default)]
pub struct ConnectionLedger {
    pub records: Vec<MutationRecord>,
    /// Inserted points (only populated under `record_points`).
    pub points: Vec<Point>,
}

/// A finished run: the measured report plus per-connection ledgers for
/// verification.
pub struct LoadOutcome {
    pub report: LoadReport,
    pub ledgers: Vec<ConnectionLedger>,
}

// ---------- shared aggregation ----------

struct Shared {
    overall: LatencyHistogram,
    per_kind: [LatencyHistogram; 4],
    staleness: StalenessTracker,
    errors: Mutex<BTreeMap<String, u64>>,
    sent: [AtomicU64; 4],
    ok: [AtomicU64; 4],
    transport_lost: AtomicU64,
    /// Successful responses the server marked `degraded` (served under
    /// a reduced scan budget).
    degraded: AtomicU64,
    /// `OVERLOADED` sheds keyed by the request's class name
    /// (`"unclassed"` for class-less envelopes).
    shed_by_class: Mutex<BTreeMap<String, u64>>,
}

impl Shared {
    fn new() -> Shared {
        Shared {
            overall: LatencyHistogram::new(),
            per_kind: std::array::from_fn(|_| LatencyHistogram::new()),
            staleness: StalenessTracker::new(),
            errors: Mutex::new(BTreeMap::new()),
            sent: std::array::from_fn(|_| AtomicU64::new(0)),
            ok: std::array::from_fn(|_| AtomicU64::new(0)),
            transport_lost: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            shed_by_class: Mutex::new(BTreeMap::new()),
        }
    }

    fn bump_error(&self, code: &str) {
        *self.errors.lock().unwrap().entry(code.to_string()).or_insert(0) += 1;
    }

    fn bump_shed(&self, class: Option<Class>) {
        let key = class.map(|c| c.as_str()).unwrap_or("unclassed");
        *self.shed_by_class.lock().unwrap().entry(key.to_string()).or_insert(0) += 1;
    }
}

/// One in-flight request.
struct Pending {
    kind: OpKind,
    sent_at: Instant,
    /// Ledger record index (mutations only).
    record: Option<usize>,
    /// Insert target id — acked inserts become delete candidates.
    target: u64,
    /// Priority class the request carried (for shed attribution).
    class: Option<Class>,
}

struct ConnShared {
    pending: Mutex<HashMap<u64, Pending>>,
    ledger: Mutex<ConnectionLedger>,
    /// Acked fresh inserts available as delete targets.
    delete_pool: Mutex<Vec<u64>>,
}

// ---------- the runner ----------

/// Drive `addr` with the configured open-loop workload. Fresh insert and
/// query points are drawn from `sampler` (the corpus's cluster model),
/// so the client never materializes the corpus.
pub fn run_load(addr: &str, opts: &LoadOptions, sampler: &PointSampler) -> Result<LoadOutcome> {
    opts.validate()?;
    let shared = Shared::new();
    let t0 = Instant::now();
    let ledgers: Vec<ConnectionLedger> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..opts.connections)
            .map(|w| {
                let shared = &shared;
                s.spawn(move || drive_connection(addr, w, opts, sampler, shared))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("loadgen connection thread panicked"))
            .collect::<Result<Vec<_>>>()
    })?;
    let wall_s = t0.elapsed().as_secs_f64();

    let mut report = empty_report(opts.rate, opts.duration.as_secs_f64(), opts.connections);
    report.wall_s = wall_s;
    for kind in OP_KINDS {
        let i = kind.index();
        let st = &mut report.per_kind[i];
        st.sent = shared.sent[i].load(Ordering::SeqCst);
        st.ok = shared.ok[i].load(Ordering::SeqCst);
        st.latency = shared.per_kind[i].summary();
        report.sent += st.sent;
        report.ok += st.ok;
    }
    report.latency = shared.overall.summary();
    report.errors = shared.errors.into_inner().unwrap();
    report.transport_lost = shared.transport_lost.load(Ordering::SeqCst);
    report.degraded = shared.degraded.load(Ordering::SeqCst);
    report.shed_by_class = shared.shed_by_class.into_inner().unwrap();
    report.staleness_count = shared.staleness.count();
    report.staleness_p50_ms = shared.staleness.p50_ms();
    report.staleness_p99_ms = shared.staleness.p99_ms();
    Ok(LoadOutcome { report, ledgers })
}

/// Best-effort: fetch the server's `stats` payload into the report (the
/// server-side staleness/overload counters complement the client view).
pub fn attach_server_stats(report: &mut LoadReport, addr: &str) {
    // Bounded connect: a wedged or partitioned node (chaos drills leave
    // those behind on purpose) must not hang the report.
    let timeout = std::time::Duration::from_secs(1);
    if let Ok(mut client) = GusClient::connect_timeout(addr, timeout) {
        let _ = client.set_read_timeout(Some(std::time::Duration::from_secs(2)));
        if let Ok(stats) = client.stats() {
            report.server_stats = Some(stats);
        }
    }
}

fn drive_connection(
    addr: &str,
    w: usize,
    opts: &LoadOptions,
    sampler: &PointSampler,
    shared: &Shared,
) -> Result<ConnectionLedger> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_nodelay(true).ok();
    let read_stream = stream.try_clone().context("clone stream")?;
    read_stream.set_read_timeout(Some(DRAIN_TIMEOUT)).ok();

    let conn = Arc::new(ConnShared {
        pending: Mutex::new(HashMap::new()),
        ledger: Mutex::new(ConnectionLedger::default()),
        delete_pool: Mutex::new(Vec::new()),
    });

    let outcome = std::thread::scope(|s| {
        let reader_conn = Arc::clone(&conn);
        let reader = s.spawn(move || reader_loop(read_stream, &reader_conn, shared));
        writer_loop(&stream, w, opts, sampler, &conn, shared);
        // Half-close: the server sees EOF, finishes the in-flight
        // requests, writes their responses, and closes — which ends the
        // reader's drain with no timeout needed.
        let _ = stream.shutdown(Shutdown::Write);
        reader.join().expect("loadgen reader thread panicked");
    });
    drop(outcome);

    let conn = Arc::into_inner(conn).expect("connection threads joined");
    Ok(conn.ledger.into_inner().unwrap())
}

/// Exponential inter-arrival draw (Poisson process at `rate`/s).
fn interarrival_s(rng: &mut Rng, rate: f64) -> f64 {
    // f64() is in [0,1); 1-u is in (0,1], so ln is finite.
    -(1.0 - rng.f64()).ln() / rate
}

fn writer_loop(
    stream: &TcpStream,
    w: usize,
    opts: &LoadOptions,
    sampler: &PointSampler,
    conn: &ConnShared,
    shared: &Shared,
) {
    let mut writer = BufWriter::new(stream.try_clone().expect("clone stream"));
    let mut rng = Rng::seeded(opts.seed).fork(w as u64);
    let per_rate = opts.rate / opts.connections as f64;
    let dur_s = opts.duration.as_secs_f64();
    // Workers mint fresh ids in disjoint ranges.
    let mut fresh_counter: u64 = 0;
    let mut fresh = move || {
        let id = FRESH_ID_BASE + ((w as u64) << 28) + fresh_counter;
        fresh_counter += 1;
        id
    };
    let mut fallback_counter: u64 = 0;
    let mut fallback = move || {
        let id = DELETE_FALLBACK_BASE + ((w as u64) << 28) + fallback_counter;
        fallback_counter += 1;
        id
    };
    let start = Instant::now();
    let mut next_arrival = interarrival_s(&mut rng, per_rate);
    let mut next_rid: u64 = 1;

    while next_arrival < dur_s {
        let target = start + Duration::from_secs_f64(next_arrival);
        if let Some(wait) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        // Open-loop: when behind schedule, send immediately — never skip.
        let kind = opts.mix.sample(&mut rng);
        let (op, record, target_id) =
            build_op(kind, opts, sampler, conn, &mut rng, &mut fresh, &mut fallback);
        // Classed runs mark queries interactive and mutations batch —
        // the generator plays the latency-sensitive user while its
        // ingest stream is deferrable.
        let class = opts.classes.then(|| {
            if kind.is_mutation() { Class::Batch } else { Class::Interactive }
        });
        let rid = next_rid;
        next_rid += 1;
        shared.sent[kind.index()].fetch_add(1, Ordering::SeqCst);
        conn.pending.lock().unwrap().insert(
            rid,
            Pending { kind, sent_at: Instant::now(), record, target: target_id, class },
        );
        let env = protocol::envelope_to_wire_classed(rid, opts.deadline_ms, class, op);
        let sent = writer
            .write_all(env.dump().as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .and_then(|_| writer.flush());
        if sent.is_err() {
            // Connection died under us (crash injection, server kill):
            // the request may or may not have reached the server — leave
            // the ledger record unacked (indeterminate) but stop
            // counting it as in-flight.
            conn.pending.lock().unwrap().remove(&rid);
            shared.transport_lost.fetch_add(1, Ordering::SeqCst);
            break;
        }
        next_arrival += interarrival_s(&mut rng, per_rate);
    }
}

/// Build one request's wire op + ledger bookkeeping.
#[allow(clippy::too_many_arguments)]
fn build_op(
    kind: OpKind,
    opts: &LoadOptions,
    sampler: &PointSampler,
    conn: &ConnShared,
    rng: &mut Rng,
    fresh: &mut impl FnMut() -> u64,
    fallback: &mut impl FnMut() -> u64,
) -> (Json, Option<usize>, u64) {
    match kind {
        OpKind::Insert => {
            let id = fresh();
            let p = sampler.sample(id, rng);
            let op = wire::insert(&p);
            let mut ledger = conn.ledger.lock().unwrap();
            let point = opts.record_points.then(|| {
                ledger.points.push(p.clone());
                ledger.points.len() - 1
            });
            ledger.records.push(MutationRecord { kind: MutKind::Insert, id, acked: false, point });
            (op, Some(ledger.records.len() - 1), id)
        }
        OpKind::Delete => {
            // Prefer deleting something this connection inserted and got
            // acked (a meaningful state change); fall back to a no-op
            // delete of a never-inserted id. Exactly one RNG draw either
            // way, so the replayed RNG stream never depends on ack
            // timing.
            let u = rng.f64();
            let id = {
                let mut pool = conn.delete_pool.lock().unwrap();
                if pool.is_empty() {
                    None
                } else {
                    let i = ((u * pool.len() as f64) as usize).min(pool.len() - 1);
                    Some(pool.swap_remove(i))
                }
            }
            .unwrap_or_else(|| fallback());
            let op = wire::delete(id);
            let mut ledger = conn.ledger.lock().unwrap();
            ledger
                .records
                .push(MutationRecord { kind: MutKind::Delete, id, acked: false, point: None });
            (op, Some(ledger.records.len() - 1), id)
        }
        OpKind::Query => {
            let p = sampler.sample(fresh(), rng);
            (wire::query(&p, Some(opts.k)), None, 0)
        }
        OpKind::QueryBatch => {
            let pts: Vec<Point> = (0..opts.batch).map(|_| sampler.sample(fresh(), rng)).collect();
            (wire::query_batch(&pts, Some(opts.k)), None, 0)
        }
    }
}

fn reader_loop(read_stream: TcpStream, conn: &ConnShared, shared: &Shared) {
    let mut reader = BufReader::new(read_stream);
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => break,          // clean EOF: server finished and closed
            Ok(_) => {}
            Err(_) => break,         // reset / drain timeout
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let Ok(parsed) = Json::parse(trimmed) else {
            shared.bump_error("TRANSPORT");
            continue;
        };
        let Ok((rid, resp)) = Response::from_wire(&parsed) else {
            shared.bump_error("TRANSPORT");
            continue;
        };
        let entry = rid.and_then(|rid| conn.pending.lock().unwrap().remove(&rid));
        let Some(entry) = entry else {
            // Connection-level refusal (admission control answers before
            // reading any request, with no correlation id).
            if let Response::Error { code, .. } = resp {
                shared.bump_error(code.as_str());
            } else {
                shared.bump_error("UNMATCHED");
            }
            continue;
        };
        let latency = entry.sent_at.elapsed();
        shared.overall.record(latency);
        shared.per_kind[entry.kind.index()].record(latency);
        match resp {
            Response::Error { code, .. } => {
                if code == crate::protocol::ErrorCode::Overloaded {
                    shared.bump_shed(entry.class);
                }
                shared.bump_error(code.as_str());
            }
            _ => {
                if matches!(
                    &resp,
                    Response::Neighbors { degraded: Some(_), .. }
                        | Response::Results { degraded: Some(_), .. }
                ) {
                    shared.degraded.fetch_add(1, Ordering::SeqCst);
                }
                shared.ok[entry.kind.index()].fetch_add(1, Ordering::SeqCst);
                if entry.kind.is_mutation() {
                    // Mutations are applied before the ack, so submit→ack
                    // bounds when the mutation is visible to queries.
                    shared.staleness.record_visible(latency);
                    if let Some(ri) = entry.record {
                        conn.ledger.lock().unwrap().records[ri].acked = true;
                    }
                    if entry.kind == OpKind::Insert {
                        conn.delete_pool.lock().unwrap().push(entry.target);
                    }
                }
            }
        }
    }
    // Whatever is still pending will never be answered.
    let left = conn.pending.lock().unwrap().len() as u64;
    if left > 0 {
        shared.transport_lost.fetch_add(left, Ordering::SeqCst);
    }
}
