//! Operation-mixture specs for the load generator.
//!
//! A mixture assigns a non-negative weight to each request kind the
//! generator can issue; each scheduled arrival samples one kind with
//! probability proportional to its weight. The CLI spelling is
//! `insert=10,delete=2,query=80,query_batch=8` — omitted kinds get
//! weight 0, and at least one weight must be positive.

use crate::util::json::Json;
use crate::util::rng::Rng;

/// The request kinds the open-loop generator can issue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Insert,
    Delete,
    Query,
    QueryBatch,
}

/// All kinds, in the canonical order used for per-kind accounting.
pub const OP_KINDS: [OpKind; 4] =
    [OpKind::Insert, OpKind::Delete, OpKind::Query, OpKind::QueryBatch];

impl OpKind {
    /// Stable index into per-kind accounting arrays.
    pub fn index(self) -> usize {
        match self {
            OpKind::Insert => 0,
            OpKind::Delete => 1,
            OpKind::Query => 2,
            OpKind::QueryBatch => 3,
        }
    }

    /// The wire op name (matches `protocol::Request::op_name`).
    pub fn name(self) -> &'static str {
        match self {
            OpKind::Insert => "insert",
            OpKind::Delete => "delete",
            OpKind::Query => "query",
            OpKind::QueryBatch => "query_batch",
        }
    }

    pub fn is_mutation(self) -> bool {
        matches!(self, OpKind::Insert | OpKind::Delete)
    }

    fn parse(s: &str) -> Option<OpKind> {
        match s {
            "insert" => Some(OpKind::Insert),
            "delete" => Some(OpKind::Delete),
            "query" => Some(OpKind::Query),
            "query_batch" => Some(OpKind::QueryBatch),
            _ => None,
        }
    }
}

/// A normalized operation mixture.
#[derive(Debug, Clone, PartialEq)]
pub struct Mix {
    /// Weights by [`OpKind::index`]; at least one is positive.
    weights: [f64; 4],
}

impl Mix {
    /// Build from per-kind weights (need not sum to anything particular).
    pub fn new(insert: f64, delete: f64, query: f64, query_batch: f64) -> Result<Mix, String> {
        let weights = [insert, delete, query, query_batch];
        for (w, kind) in weights.iter().zip(OP_KINDS) {
            if !w.is_finite() || *w < 0.0 {
                return Err(format!("mix weight for {} must be finite and >= 0", kind.name()));
            }
        }
        if weights.iter().sum::<f64>() <= 0.0 {
            return Err("mix needs at least one positive weight".into());
        }
        Ok(Mix { weights })
    }

    /// The ISSUE-default mixed workload: read-heavy with a steady
    /// mutation stream (`insert=10,delete=2,query=80,query_batch=8`).
    pub fn default_mixed() -> Mix {
        Mix { weights: [10.0, 2.0, 80.0, 8.0] }
    }

    /// Queries only (used by post-recovery SLO re-checks).
    pub fn query_only() -> Mix {
        Mix { weights: [0.0, 0.0, 1.0, 0.0] }
    }

    /// Parse the CLI spelling: comma-separated `kind=weight` pairs.
    pub fn parse(spec: &str) -> Result<Mix, String> {
        let mut weights = [0.0f64; 4];
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, value) = part
                .split_once('=')
                .ok_or_else(|| format!("bad mix component '{part}' (want kind=weight)"))?;
            let kind = OpKind::parse(name.trim())
                .ok_or_else(|| format!("unknown op kind '{}' in mix", name.trim()))?;
            let w: f64 = value
                .trim()
                .parse()
                .map_err(|_| format!("bad weight '{}' for {}", value.trim(), kind.name()))?;
            weights[kind.index()] += w;
        }
        Mix::new(weights[0], weights[1], weights[2], weights[3])
    }

    /// Fraction of arrivals of `kind` (weights normalized).
    pub fn fraction(&self, kind: OpKind) -> f64 {
        self.weights[kind.index()] / self.weights.iter().sum::<f64>()
    }

    /// Does the mixture issue any mutations at all?
    pub fn has_mutations(&self) -> bool {
        self.weights[OpKind::Insert.index()] > 0.0 || self.weights[OpKind::Delete.index()] > 0.0
    }

    /// Sample one kind (inverse-CDF over the weights).
    pub fn sample(&self, rng: &mut Rng) -> OpKind {
        let total: f64 = self.weights.iter().sum();
        let mut u = rng.f64() * total;
        for kind in OP_KINDS {
            u -= self.weights[kind.index()];
            if u < 0.0 {
                return kind;
            }
        }
        // Float edge (u == total): the last kind with positive weight.
        *OP_KINDS
            .iter()
            .rev()
            .find(|k| self.weights[k.index()] > 0.0)
            .expect("Mix invariant: at least one positive weight")
    }

    /// The canonical spelling (round-trips through [`Mix::parse`]).
    pub fn spec_string(&self) -> String {
        OP_KINDS
            .iter()
            .map(|k| format!("{}={}", k.name(), self.weights[k.index()]))
            .collect::<Vec<_>>()
            .join(",")
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            OP_KINDS
                .iter()
                .map(|k| (k.name().to_string(), Json::num(self.weights[k.index()])))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_spelling() {
        let m = Mix::parse("insert=10,delete=2,query=80,query_batch=8").unwrap();
        assert_eq!(m, Mix::default_mixed());
        assert!((m.fraction(OpKind::Query) - 0.8).abs() < 1e-12);
        assert!(m.has_mutations());
    }

    #[test]
    fn omitted_kinds_get_zero_weight() {
        let m = Mix::parse("query=1").unwrap();
        assert_eq!(m.fraction(OpKind::Query), 1.0);
        assert_eq!(m.fraction(OpKind::Insert), 0.0);
        assert!(!m.has_mutations());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Mix::parse("").is_err(), "all-zero mix");
        assert!(Mix::parse("query").is_err(), "missing =weight");
        assert!(Mix::parse("frobnicate=3").is_err(), "unknown kind");
        assert!(Mix::parse("query=-1").is_err(), "negative weight");
        assert!(Mix::parse("query=NaN").is_err(), "non-finite weight");
        assert!(Mix::new(0.0, 0.0, 0.0, 0.0).is_err(), "no positive weight");
    }

    #[test]
    fn round_trips_through_spec_string() {
        let m = Mix::parse("insert=3,query=7").unwrap();
        assert_eq!(Mix::parse(&m.spec_string()).unwrap(), m);
    }

    #[test]
    fn sampling_tracks_weights() {
        let m = Mix::parse("insert=25,query=75").unwrap();
        let mut rng = Rng::seeded(7);
        let mut counts = [0u32; 4];
        for _ in 0..20_000 {
            counts[m.sample(&mut rng).index()] += 1;
        }
        assert_eq!(counts[OpKind::Delete.index()], 0);
        assert_eq!(counts[OpKind::QueryBatch.index()], 0);
        let ins = counts[OpKind::Insert.index()] as f64 / 20_000.0;
        assert!((0.22..0.28).contains(&ins), "insert fraction {ins}");
    }
}
