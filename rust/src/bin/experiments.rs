//! Experiment driver: regenerates every figure/table of the paper.
//!
//! ```text
//! experiments all        [--n-arxiv N] [--n-products N] [--threads T]
//! experiments fig3|fig4|fig5|fig6|fig7|fig8   [--dataset arxiv_like]
//! experiments fig9       # also emits Fig-10 tables + insertion stats
//! experiments dynamic --dataset D --nn K --idf-s S --filter-p P --json
//! ```
//!
//! Quality figures (3–8) write `results/figN_<dataset>.csv` percentile
//! curves + ASCII plots; Fig 9/10 spawn one subprocess per configuration
//! (per-config peak RSS, like the paper's one-experiment-at-a-time setup)
//! and write latency/CPU/memory tables. `results/SUMMARY.md` accumulates
//! the markdown rendition of everything.

use std::process::Command;

use dynamic_gus::config::ScorerKind;
use dynamic_gus::data::Dataset;
use dynamic_gus::eval::dynamic::{run_dynamic, DynamicOutput, DynamicParams};
use dynamic_gus::eval::offline;
use dynamic_gus::eval::report::{self, Series};
use dynamic_gus::eval::{dataset_names, default_n, load_dataset};
use dynamic_gus::util::cli::Args;
use dynamic_gus::util::json::Json;

fn main() {
    let args = Args::from_env().unwrap_or_else(|e| {
        eprintln!("argument error: {e}");
        std::process::exit(2);
    });
    let cmd = args.command.clone().unwrap_or_else(|| "all".to_string());
    let code = run(&cmd, &args);
    if let Err(e) = args.check_unused() {
        eprintln!("warning: {e}");
    }
    std::process::exit(code);
}

struct Ctx {
    threads: usize,
    datasets: Vec<(String, usize)>,
    quick: bool,
}

impl Ctx {
    fn from_args(args: &Args) -> Ctx {
        let threads = args.get_usize(
            "threads",
            dynamic_gus::util::threadpool::default_parallelism(),
        );
        let quick = args.get_bool("quick", false);
        let scale = |name: &str| {
            let d = if quick { 2_000 } else { default_n(name) };
            args.get_usize(&format!("n-{}", name.replace("_like", "")), d)
        };
        let only = args.opt_str("dataset");
        let datasets = dataset_names()
            .iter()
            .filter(|n| only.as_deref().map_or(true, |o| o == **n))
            .map(|n| (n.to_string(), scale(n)))
            .collect();
        Ctx { threads, datasets, quick }
    }

    fn load(&self, name: &str, n: usize) -> Dataset {
        eprintln!("[data] generating {name} (n={n})...");
        load_dataset(name, n)
    }
}

fn run(cmd: &str, args: &Args) -> i32 {
    match cmd {
        "fig3" => fig3(&Ctx::from_args(args)),
        "fig4" => fig4(&Ctx::from_args(args)),
        "fig5" => fig_topk(&Ctx::from_args(args), 10, "fig5"),
        "fig6" => fig6(&Ctx::from_args(args)),
        "fig7" => fig7(&Ctx::from_args(args)),
        "fig8" => fig_topk(&Ctx::from_args(args), 100, "fig8"),
        "fig9" => fig9_fig10(&Ctx::from_args(args), args),
        "ablation" => ablation(&Ctx::from_args(args)),
        "dynamic" => dynamic_single(args),
        "all" => {
            let ctx = Ctx::from_args(args);
            let mut rc = 0;
            rc |= fig3(&ctx);
            rc |= fig4(&ctx);
            rc |= fig_topk(&ctx, 10, "fig5");
            rc |= fig6(&ctx);
            rc |= fig7(&ctx);
            rc |= fig_topk(&ctx, 100, "fig8");
            rc |= fig9_fig10(&ctx, args);
            rc |= ablation(&ctx);
            rc
        }
        other => {
            eprintln!("unknown command '{other}'");
            2
        }
    }
}

fn emit_figure(name: &str, dataset: &str, title: &str, series: &[Series]) {
    let csv = report::write_csv(&format!("{name}_{dataset}"), series).expect("write csv");
    let plot = report::ascii_plot(title, series, 64, 16);
    println!("{plot}");
    println!("[{name}] wrote {}", csv.display());
    let mut md = format!("## {title}\n\n```\n{plot}```\n");
    md.push_str(&format!("CSV: `{}`\n", csv.display()));
    report::append_summary(&md).ok();
}

fn fig3(ctx: &Ctx) -> i32 {
    let mut rc = 0;
    for (name, n) in &ctx.datasets {
        let ds = ctx.load(name, *n);
        let (series, identical) = offline::fig3(&ds, ctx.threads);
        emit_figure(
            "fig3",
            name,
            &format!("Fig 3 — {name}: Grale(no split) vs GUS(all negative dist)"),
            &series,
        );
        println!(
            "[fig3] {name}: identical={identical} edges={} (Lemma 4.1 {})",
            series[0].total_edges,
            if identical { "VALIDATED" } else { "VIOLATED" }
        );
        if !identical {
            rc = 1;
        }
    }
    rc
}

fn fig4(ctx: &Ctx) -> i32 {
    // Paper grid: per dataset, subplots (a–f) = NN ∈ {10,100,1000} with
    // IDF-S ∈ {0, 10^6, 10^7|10^8} × Filter-P ∈ {0, 10}.
    let nns: &[usize] = if ctx.quick { &[10, 100] } else { &[10, 100, 1000] };
    for (name, n) in &ctx.datasets {
        let ds = ctx.load(name, *n);
        let idf_sizes: Vec<usize> = if name == "arxiv_like" {
            vec![0, 1_000_000, 10_000_000]
        } else {
            vec![0, 10_000_000, 100_000_000]
        };
        for &nn in nns {
            let series = offline::fig4_grid(&ds, nn, &idf_sizes, ctx.threads);
            emit_figure(
                &format!("fig4_nn{nn}"),
                name,
                &format!("Fig 4 — {name}: GUS ScaNN-NN={nn}, IDF/Filter sweep"),
                &series,
            );
        }
    }
    0
}

fn fig_topk(ctx: &Ctx, k: usize, figname: &str) -> i32 {
    for (name, n) in &ctx.datasets {
        let ds = ctx.load(name, *n);
        let series = offline::fig_topk(&ds, k, ctx.threads);
        emit_figure(
            figname,
            name,
            &format!(
                "{figname} — {name}: Grale Top-K={k} Bucket-S={} vs GUS NN={k}",
                dynamic_gus::eval::offline::scaled_bucket_s(ds.points.len())
            ),
            &series,
        );
    }
    0
}

fn fig6(ctx: &Ctx) -> i32 {
    let nns: &[usize] = if ctx.quick { &[10, 100] } else { &[10, 100, 1000] };
    for (name, n) in &ctx.datasets {
        let ds = ctx.load(name, *n);
        let series = offline::fig6(&ds, nns, ctx.threads);
        emit_figure(
            "fig6",
            name,
            &format!("Fig 6 — {name}: Grale Bucket-S=1000 vs GUS by NN"),
            &series,
        );
    }
    0
}

fn fig7(ctx: &Ctx) -> i32 {
    // The paper sweeps Bucket-S ∈ {10, 100, 1000}; quality increases with
    // Bucket-S (Fig. 7). The absolute sizes are meaningful relative to the
    // corpus, so we keep the paper's sweep literally (it spans the same
    // no-op → heavy-split range at our scale).
    let sizes: &[usize] = if ctx.quick { &[10, 100] } else { &[10, 100, 1000] };
    for (name, n) in &ctx.datasets {
        let ds = ctx.load(name, *n);
        let series = offline::fig7(&ds, sizes, ctx.threads);
        emit_figure(
            "fig7",
            name,
            &format!("Fig 7 — {name}: Grale by Bucket-S"),
            &series,
        );
    }
    0
}

/// Figs. 9 + 10 + §5.2 insertion: one subprocess per configuration.
fn fig9_fig10(ctx: &Ctx, args: &Args) -> i32 {
    let self_exe = std::env::current_exe().expect("current_exe");
    let n_queries = args.get_usize("queries", if ctx.quick { 500 } else { 10_000 });
    let nns: &[usize] = if ctx.quick { &[10, 100] } else { &[10, 100, 1000] };
    for (name, n) in &ctx.datasets {
        let idf_sizes: Vec<usize> = if name == "arxiv_like" {
            vec![0, 1_000_000, 10_000_000]
        } else {
            vec![0, 10_000_000, 100_000_000]
        };
        let mut rows_lat: Vec<Vec<String>> = Vec::new();
        let mut rows_mem: Vec<Vec<String>> = Vec::new();
        let mut insert_summary: Option<DynamicOutput> = None;
        for &nn in nns {
            for &idf_s in &idf_sizes {
                for &filter_p in &[0.0f64, 10.0] {
                    eprintln!(
                        "[fig9] {name} NN={nn} IDF-S={idf_s} Filter-P={filter_p} ..."
                    );
                    let out = Command::new(&self_exe)
                        .args([
                            "dynamic",
                            "--json",
                            &format!("--dataset={name}"),
                            &format!("--n={n}"),
                            &format!("--nn={nn}"),
                            &format!("--idf-s={idf_s}"),
                            &format!("--filter-p={filter_p}"),
                            &format!("--queries={n_queries}"),
                        ])
                        .output()
                        .expect("spawn dynamic subprocess");
                    if !out.status.success() {
                        eprintln!(
                            "[fig9] subprocess failed: {}",
                            String::from_utf8_lossy(&out.stderr)
                        );
                        return 1;
                    }
                    let text = String::from_utf8_lossy(&out.stdout);
                    let line = text.lines().last().unwrap_or("");
                    let j = Json::parse(line).expect("subprocess json");
                    let d = DynamicOutput::from_json(&j).expect("dynamic output");
                    rows_lat.push(vec![
                        nn.to_string(),
                        idf_s.to_string(),
                        format!("{filter_p}"),
                        format!("{:.2}", d.query_ms.p50),
                        format!("{:.2}", d.query_ms.p90),
                        format!("{:.2}", d.query_ms.p95),
                        format!("{:.2}", d.query_ms.p99),
                        format!("{:.2}", d.query_ms.max),
                    ]);
                    rows_mem.push(vec![
                        nn.to_string(),
                        idf_s.to_string(),
                        format!("{filter_p}"),
                        format!("{:.2}", d.avg_cpu_ms_per_query),
                        format!("{:.0}", d.peak_rss_mib),
                    ]);
                    insert_summary = Some(d);
                }
            }
        }
        let lat_hdr = [
            "ScaNN-NN", "IDF-S", "Filter-P", "p50_ms", "p90_ms", "p95_ms", "p99_ms", "max_ms",
        ];
        let mem_hdr = [
            "ScaNN-NN", "IDF-S", "Filter-P", "avg_cpu_ms_per_query", "peak_rss_mib",
        ];
        let p1 = report::write_rows_csv(&format!("fig9_{name}"), &lat_hdr, &rows_lat).unwrap();
        let p2 = report::write_rows_csv(&format!("fig10_{name}"), &mem_hdr, &rows_mem).unwrap();
        println!("[fig9]  {name}: wrote {}", p1.display());
        println!("[fig10] {name}: wrote {}", p2.display());
        let md = format!(
            "## Fig 9 — {name}: query latency (ms)\n\n{}\n## Fig 10 — {name}: CPU/memory\n\n{}",
            report::markdown_table(&lat_hdr, &rows_lat),
            report::markdown_table(&mem_hdr, &rows_mem)
        );
        println!("{md}");
        report::append_summary(&md).ok();
        if let Some(d) = insert_summary {
            let ins = format!(
                "§5.2 insertion ({name}, last config): median {:.3} ms, 95%ile {:.3} ms (n={})",
                d.insert_ms.p50, d.insert_ms.p95, d.insert_ms.count
            );
            println!("{ins}");
            report::append_summary(&ins).ok();
        }
    }
    0
}

/// Ablation (DESIGN.md §Key-decisions #1): the `max_postings` approximation
/// budget emulating ScaNN's recall/latency dial on the otherwise-exact index.
fn ablation(ctx: &Ctx) -> i32 {
    for (name, n) in &ctx.datasets {
        let ds = ctx.load(name, *n);
        let budgets = [0usize, 1_000, 10_000, 100_000];
        // One embed+index pass shared by both budget sweeps.
        let (index, embeddings) = offline::ablation_setup(&ds, ctx.threads);
        let rows = offline::ablation_max_postings(
            &index, &embeddings, &ds, 10, &budgets, ctx.threads,
        );
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|&(b, w, e)| {
                vec![
                    if b == 0 { "exact".to_string() } else { b.to_string() },
                    format!("{w:.4}"),
                    e.to_string(),
                ]
            })
            .collect();
        let hdr = ["max_postings", "mean_edge_weight", "edges"];
        let p = report::write_rows_csv(&format!("ablation_postings_{name}"), &hdr, &table)
            .unwrap();
        let md = format!(
            "## Ablation — {name}: posting-scan budget (ScaNN approximation dial)\n\n{}",
            report::markdown_table(&hdr, &table)
        );
        println!("{md}\n[ablation] wrote {}", p.display());
        report::append_summary(&md).ok();

        // Dim-order ablation: how the budget is spent (selectivity order
        // vs the seed's query order) at the same scan volume.
        let rows = offline::ablation_dim_order(
            &index, &embeddings, &ds, 10, &budgets, ctx.threads,
        );
        let table: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    if r.budget == 0 { "exact".to_string() } else { r.budget.to_string() },
                    format!("{:.4}", r.recall_selectivity),
                    format!("{:.4}", r.recall_query_order),
                    format!("{:.1}", r.scanned_selectivity),
                    format!("{:.1}", r.scanned_query_order),
                ]
            })
            .collect();
        let hdr = [
            "max_postings",
            "recall@10 selectivity",
            "recall@10 query-order",
            "scanned/query sel",
            "scanned/query qo",
        ];
        let p = report::write_rows_csv(&format!("ablation_dim_order_{name}"), &hdr, &table)
            .unwrap();
        let md = format!(
            "## Ablation — {name}: budgeted-scan dim order (recall per scanned posting)\n\n{}",
            report::markdown_table(&hdr, &table)
        );
        println!("{md}\n[ablation] wrote {}", p.display());
        report::append_summary(&md).ok();
    }
    0
}

/// One dynamic configuration in-process (used as the per-config subprocess).
fn dynamic_single(args: &Args) -> i32 {
    let name = args.get_str("dataset", "arxiv_like");
    let n = args.get_usize("n", default_n(&name));
    let params = DynamicParams {
        scann_nn: args.get_usize("nn", 10),
        idf_s: args.get_usize("idf-s", 0),
        filter_p: args.get_f64("filter-p", 0.0),
        n_queries: args.get_usize("queries", 10_000),
        n_inserts: args.get_usize("inserts", 1_000),
        scorer: ScorerKind::parse(&args.get_str("scorer", "auto")).unwrap(),
        seed: args.get_u64("seed", 0xd1a),
    };
    let json_out = args.get_bool("json", false);
    let ds = load_dataset(&name, n);
    match run_dynamic(&ds, &params) {
        Ok(out) => {
            if json_out {
                println!("{}", out.to_json().dump());
            } else {
                println!(
                    "{name} n={n} NN={} IDF-S={} Filter-P={}: query p50 {:.2} ms p99 {:.2} ms; \
                     insert p50 {:.3} ms p95 {:.3} ms; cpu {:.2} ms/q; peak rss {:.0} MiB",
                    params.scann_nn,
                    params.idf_s,
                    params.filter_p,
                    out.query_ms.p50,
                    out.query_ms.p99,
                    out.insert_ms.p50,
                    out.insert_ms.p95,
                    out.avg_cpu_ms_per_query,
                    out.peak_rss_mib
                );
            }
            0
        }
        Err(e) => {
            eprintln!("dynamic run failed: {e}");
            1
        }
    }
}
