//! Service metrics: log-bucketed latency histograms, counters, RSS probe.
//!
//! Fig. 9 plots per-configuration latency distributions and Fig. 10 reports
//! average CPU time per query and maximum memory usage — this module
//! provides the measurement substrate for both.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Log-bucketed histogram for durations (ns). Two buckets per octave from
/// 1 ns to ~18 s; records are lock-free.
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
    min_ns: AtomicU64,
}

const SUB_BUCKETS_LOG2: u32 = 3; // 8 sub-buckets per octave → ≤ ~9% error
const NUM_BUCKETS: usize = (64 - SUB_BUCKETS_LOG2 as usize) << SUB_BUCKETS_LOG2;

#[inline]
fn bucket_index(ns: u64) -> usize {
    let ns = ns.max(1);
    let msb = 63 - ns.leading_zeros();
    if msb < SUB_BUCKETS_LOG2 {
        return ns as usize;
    }
    let sub = ((ns >> (msb - SUB_BUCKETS_LOG2)) & ((1 << SUB_BUCKETS_LOG2) - 1)) as usize;
    (((msb - SUB_BUCKETS_LOG2 + 1) as usize) << SUB_BUCKETS_LOG2) + sub
}

#[inline]
fn bucket_lower_bound(idx: usize) -> u64 {
    let sb = SUB_BUCKETS_LOG2 as usize;
    if idx < (1 << sb) {
        return idx as u64;
    }
    let oct = (idx >> sb) - 1;
    let sub = (idx & ((1 << sb) - 1)) as u64;
    ((1u64 << sb) + sub) << oct
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            min_ns: AtomicU64::new(u64::MAX),
        }
    }

    /// Record one duration.
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos() as u64)
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        self.min_ns.fetch_min(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Approximate quantile (bucket lower bound), q in [0,1].
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            // RELAXED: quantiles over a live histogram are approximate by
            // design; torn cross-bucket snapshots only shift an estimate.
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return bucket_lower_bound(i);
            }
        }
        self.max_ns()
    }

    /// Standard summary for reports.
    pub fn summary(&self) -> LatencySummary {
        LatencySummary {
            count: self.count(),
            mean_ns: self.mean_ns(),
            p50_ns: self.quantile_ns(0.50),
            p90_ns: self.quantile_ns(0.90),
            p95_ns: self.quantile_ns(0.95),
            p99_ns: self.quantile_ns(0.99),
            max_ns: self.max_ns(),
        }
    }

    /// Reset all counters.
    pub fn reset(&self) {
        for b in &self.buckets {
            // RELAXED: reset racing concurrent recorders is inherently
            // best-effort; each cell is independent and monotonicity is
            // not assumed by any reader.
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
        self.max_ns.store(0, Ordering::Relaxed);
        self.min_ns.store(u64::MAX, Ordering::Relaxed);
    }
}

/// Snapshot of a latency histogram.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p90_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
}

impl LatencySummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean_ms", Json::num(self.mean_ns / 1e6)),
            ("p50_ms", Json::num(self.p50_ns as f64 / 1e6)),
            ("p90_ms", Json::num(self.p90_ns as f64 / 1e6)),
            ("p95_ms", Json::num(self.p95_ns as f64 / 1e6)),
            ("p99_ms", Json::num(self.p99_ns as f64 / 1e6)),
            ("max_ms", Json::num(self.max_ns as f64 / 1e6)),
        ])
    }
}

/// Service counters for the coordinator.
#[derive(Default)]
pub struct Counters {
    pub inserts: AtomicU64,
    pub updates: AtomicU64,
    pub deletes: AtomicU64,
    pub queries: AtomicU64,
    pub errors: AtomicU64,
    pub candidates_retrieved: AtomicU64,
    pub pairs_scored: AtomicU64,
    /// Wall-clock nanoseconds spent inside pair scoring (the
    /// `PairScorer::score_into` span, excluding feature fetch and the
    /// result sort). `pairs_scored / (pairs_scored_ns / 1e9)` is the
    /// served pairs/sec figure `scorer_bench` tracks offline.
    pub pairs_scored_ns: AtomicU64,
    /// Connections refused at the concurrency cap (each gets a final
    /// `OVERLOADED` response before the socket closes).
    pub refused: AtomicU64,
    /// Requests shed because the server's run queue was full.
    pub overloaded: AtomicU64,
    /// Requests rejected because their deadline expired before execution.
    pub deadline_exceeded: AtomicU64,
    /// Interactive-class requests shed by the adaptive admission
    /// controller (past the quality floor; distinct from `overloaded`,
    /// the queue-full backstop).
    pub shed_interactive: AtomicU64,
    /// Replication-class requests shed by the admission controller.
    pub shed_replication: AtomicU64,
    /// Batch-class requests shed by the admission controller.
    pub shed_batch: AtomicU64,
    /// Query responses served under a reduced `max_postings` budget
    /// (marked `degraded: true` on the wire).
    pub degraded_responses: AtomicU64,
}

impl Counters {
    pub fn to_json(&self) -> Json {
        // RELAXED: stats snapshots read independent counters; slight skew
        // between fields is acceptable in a monitoring endpoint.
        let g = |a: &AtomicU64| Json::num(a.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("inserts", g(&self.inserts)),
            ("updates", g(&self.updates)),
            ("deletes", g(&self.deletes)),
            ("queries", g(&self.queries)),
            ("errors", g(&self.errors)),
            ("candidates_retrieved", g(&self.candidates_retrieved)),
            ("pairs_scored", g(&self.pairs_scored)),
            ("pairs_scored_ns", Json::u64(self.pairs_scored_ns.load(Ordering::Relaxed))),
            ("refused", g(&self.refused)),
            ("overloaded", g(&self.overloaded)),
            ("deadline_exceeded", g(&self.deadline_exceeded)),
            ("shed_interactive", g(&self.shed_interactive)),
            ("shed_replication", g(&self.shed_replication)),
            ("shed_batch", g(&self.shed_batch)),
            ("degraded_responses", g(&self.degraded_responses)),
        ])
    }
}

/// The node's replication role, as exposed in `stats`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicationRole {
    /// Replication not enabled (single-node serving).
    Single,
    /// Accepts mutations and streams its WAL to subscribed followers.
    Leader,
    /// Applies the leader's stream; mutations answered with `NOT_LEADER`.
    Follower,
}

impl ReplicationRole {
    pub fn as_str(self) -> &'static str {
        match self {
            ReplicationRole::Single => "single",
            ReplicationRole::Leader => "leader",
            ReplicationRole::Follower => "follower",
        }
    }
}

/// Nanoseconds on a process-local monotonic clock (first call is 0).
/// Replication code reads time exclusively through [`ReplicationGauges`]
/// or [`monotonic_ms`] so `replication/*.rs` stays free of
/// `Instant::now` — the replay-determinism lint covers those files.
fn monotonic_ns() -> u64 {
    use std::sync::OnceLock;
    static START: OnceLock<std::time::Instant> = OnceLock::new();
    START.get_or_init(std::time::Instant::now).elapsed().as_nanos() as u64
}

/// Milliseconds on the process-local monotonic clock (first call is 0).
/// The sanctioned way for lint-covered modules (replication, fault) to
/// measure elapsed wall time for deadlines and stall detection.
pub fn monotonic_ms() -> u64 {
    monotonic_ns() / 1_000_000
}

/// Fault-injection and resilience counters: what the `stats` RPC reports
/// under `"faults"`. Injected counts are bumped by
/// [`crate::fault::FaultInjector::check`] when a plan rule fires;
/// backoff/circuit counters by [`crate::fault::Backoff`]. All zero on a
/// process with no fault plan and no retries.
#[derive(Default)]
pub struct FaultGauges {
    injected_enospc: AtomicU64,
    injected_err: AtomicU64,
    injected_torn: AtomicU64,
    injected_crash: AtomicU64,
    /// Backoff delays handed out across all retry loops.
    backoff_retries: AtomicU64,
    /// Retry streaks that reached the backoff cap (remote considered
    /// down; retries at maximum spacing until reset).
    circuit_open_windows: AtomicU64,
}

impl FaultGauges {
    /// A plan rule fired; `kind` is [`crate::fault::FaultKind::name`].
    pub fn note_injected(&self, kind: &str) {
        let c = match kind {
            "enospc" => &self.injected_enospc,
            "err" => &self.injected_err,
            "torn" => &self.injected_torn,
            _ => &self.injected_crash,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// A backoff delay was computed (the caller is about to sleep it).
    pub fn note_backoff_retry(&self) {
        self.backoff_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// A retry streak saturated at the backoff cap.
    pub fn note_circuit_open(&self) {
        self.circuit_open_windows.fetch_add(1, Ordering::Relaxed);
    }

    pub fn injected_total(&self) -> u64 {
        // RELAXED: independent counters summed for a monitoring snapshot.
        self.injected_enospc.load(Ordering::Relaxed)
            + self.injected_err.load(Ordering::Relaxed)
            + self.injected_torn.load(Ordering::Relaxed)
            + self.injected_crash.load(Ordering::Relaxed)
    }

    pub fn backoff_retries(&self) -> u64 {
        self.backoff_retries.load(Ordering::Relaxed)
    }

    pub fn circuit_open_windows(&self) -> u64 {
        self.circuit_open_windows.load(Ordering::Relaxed)
    }

    /// The `"faults"` section of `stats`.
    pub fn to_json(&self) -> Json {
        // RELAXED: stats snapshots read independent counters; slight skew
        // between fields is acceptable in a monitoring endpoint.
        let g = |a: &AtomicU64| Json::u64(a.load(Ordering::Relaxed));
        Json::obj(vec![
            (
                "injected",
                Json::obj(vec![
                    ("enospc", g(&self.injected_enospc)),
                    ("err", g(&self.injected_err)),
                    ("torn", g(&self.injected_torn)),
                    ("crash", g(&self.injected_crash)),
                ]),
            ),
            ("backoff_retries", g(&self.backoff_retries)),
            ("circuit_open_windows", g(&self.circuit_open_windows)),
        ])
    }
}

/// The process-wide fault gauges (one set per process, like the global
/// fault injector they mirror).
pub fn faults() -> &'static FaultGauges {
    use std::sync::OnceLock;
    static GAUGES: OnceLock<FaultGauges> = OnceLock::new();
    GAUGES.get_or_init(FaultGauges::default)
}

/// Replication health gauges: what the `stats` RPC reports under
/// `"replication"` and what the router's failover logic reads. All
/// fields are plain gauges updated by the replication subsystem; a
/// single-node server reports role `single` with zeroed gauges.
#[derive(Default)]
pub struct ReplicationGauges {
    /// 0 = single, 1 = leader, 2 = follower (see [`ReplicationRole`]).
    role: AtomicU64,
    /// Leader address hint served with `NOT_LEADER` errors (follower only).
    leader_hint: std::sync::Mutex<Option<String>>,
    /// Highest WAL seq received from the leader's stream (follower).
    last_received_seq: AtomicU64,
    /// Highest WAL seq durably appended + applied locally (follower).
    last_applied_seq: AtomicU64,
    /// Monotonic timestamp of the last applied record (0 = never).
    last_apply_ns: AtomicU64,
    /// WAL records shipped to followers (leader, cumulative).
    records_shipped: AtomicU64,
    /// Mutation acks gated on replication that timed out (leader).
    ack_timeouts: AtomicU64,
    /// Ack-timeout counts per laggard subscriber (leader): subscriber
    /// stream id → how many gated acks timed out while that subscriber
    /// had not acked. BTreeMap, not HashMap — this file feeds stats for
    /// lint-covered modules and deterministic iteration keeps the
    /// `"replication"` section byte-stable across runs.
    ack_timeouts_by_subscriber: std::sync::Mutex<std::collections::BTreeMap<u64, u64>>,
    /// Live `wal_subscribe` streams (leader).
    subscribers: AtomicU64,
}

impl ReplicationGauges {
    pub fn set_role(&self, role: ReplicationRole) {
        let v = match role {
            ReplicationRole::Single => 0,
            ReplicationRole::Leader => 1,
            ReplicationRole::Follower => 2,
        };
        self.role.store(v, Ordering::Relaxed);
    }

    pub fn role(&self) -> ReplicationRole {
        // RELAXED: role transitions are rare and monitoring/denial paths
        // tolerate reading the old role for one request.
        match self.role.load(Ordering::Relaxed) {
            1 => ReplicationRole::Leader,
            2 => ReplicationRole::Follower,
            _ => ReplicationRole::Single,
        }
    }

    pub fn set_leader_hint(&self, addr: Option<String>) {
        *self.leader_hint.lock().unwrap() = addr;
    }

    pub fn leader_hint(&self) -> Option<String> {
        self.leader_hint.lock().unwrap().clone()
    }

    /// Follower: a frame arrived off the wire (not yet durable/applied).
    pub fn note_received(&self, seq: u64) {
        self.last_received_seq.fetch_max(seq, Ordering::Relaxed);
    }

    /// Follower: a record is durably appended and applied. Stamps the
    /// apply-staleness clock.
    pub fn note_applied(&self, seq: u64) {
        self.last_applied_seq.fetch_max(seq, Ordering::Relaxed);
        self.last_apply_ns.store(monotonic_ns().max(1), Ordering::Relaxed);
    }

    pub fn last_received_seq(&self) -> u64 {
        self.last_received_seq.load(Ordering::Relaxed)
    }

    pub fn last_applied_seq(&self) -> u64 {
        self.last_applied_seq.load(Ordering::Relaxed)
    }

    /// Records received but not yet applied (follower catch-up distance).
    pub fn lag_records(&self) -> u64 {
        self.last_received_seq().saturating_sub(self.last_applied_seq())
    }

    /// Milliseconds since the last applied record (0 = nothing applied
    /// yet). On an idle stream this grows, which is exactly what a
    /// dashboard wants to see: "how stale could this follower be".
    pub fn apply_staleness_ms(&self) -> f64 {
        let at = self.last_apply_ns.load(Ordering::Relaxed);
        if at == 0 {
            return 0.0;
        }
        monotonic_ns().saturating_sub(at) as f64 / 1e6
    }

    /// Leader: `n` WAL records went out to some subscriber.
    pub fn note_shipped(&self, n: u64) {
        self.records_shipped.fetch_add(n, Ordering::Relaxed);
    }

    /// Leader: a replication-gated mutation ack timed out. `laggards`
    /// lists the subscriber stream ids that had not acked when the
    /// timeout fired.
    pub fn note_ack_timeout(&self, laggards: &[u64]) {
        self.ack_timeouts.fetch_add(1, Ordering::Relaxed);
        let mut by_sub = self.ack_timeouts_by_subscriber.lock().unwrap();
        for id in laggards {
            *by_sub.entry(*id).or_insert(0) += 1;
        }
    }

    /// Ack-timeout count attributed to one subscriber stream (0 if it
    /// never held up a gated ack).
    pub fn ack_timeouts_for(&self, subscriber: u64) -> u64 {
        self.ack_timeouts_by_subscriber.lock().unwrap().get(&subscriber).copied().unwrap_or(0)
    }

    /// Leader: a subscription stream closed — drop its attribution row.
    /// Stream ids are per-connection, so without pruning a long-lived
    /// leader with follower churn plus ack timeouts grows the map (and
    /// the stats JSON) without bound. The aggregate `ack_timeouts`
    /// counter keeps the full history.
    pub fn forget_subscriber(&self, subscriber: u64) {
        self.ack_timeouts_by_subscriber.lock().unwrap().remove(&subscriber);
    }

    pub fn subscriber_connected(&self) {
        self.subscribers.fetch_add(1, Ordering::Relaxed);
    }

    pub fn subscriber_disconnected(&self) {
        self.subscribers.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn subscribers(&self) -> u64 {
        self.subscribers.load(Ordering::Relaxed)
    }

    /// The `"replication"` section of `stats`. `wal_last_seq` is passed in
    /// by the coordinator (it owns the WAL); `replication_lag_records` is
    /// the distance from the newest record this node knows about to what
    /// it has applied — on a follower that is stream-lag, on a leader 0.
    pub fn to_json(&self, wal_last_seq: u64) -> Json {
        // RELAXED: stats snapshots read independent gauges; slight skew
        // between fields is acceptable in a monitoring endpoint.
        let lag = match self.role() {
            ReplicationRole::Follower => {
                wal_last_seq.max(self.last_received_seq()).saturating_sub(self.last_applied_seq())
            }
            _ => 0,
        };
        Json::obj(vec![
            ("role", Json::str(self.role().as_str())),
            (
                "leader",
                match self.leader_hint() {
                    Some(a) => Json::str(a),
                    None => Json::Null,
                },
            ),
            ("wal_last_seq", Json::u64(wal_last_seq)),
            ("last_received_seq", Json::u64(self.last_received_seq())),
            ("last_applied_seq", Json::u64(self.last_applied_seq())),
            ("replication_lag_records", Json::u64(lag)),
            ("apply_staleness_ms", Json::num(self.apply_staleness_ms())),
            ("records_shipped", Json::u64(self.records_shipped.load(Ordering::Relaxed))),
            ("ack_timeouts", Json::u64(self.ack_timeouts.load(Ordering::Relaxed))),
            (
                "ack_timeouts_by_subscriber",
                Json::Obj(
                    self.ack_timeouts_by_subscriber
                        .lock()
                        .unwrap()
                        .iter()
                        .map(|(id, n)| (format!("{id}"), Json::u64(*n)))
                        .collect(),
                ),
            ),
            ("subscribers", Json::u64(self.subscribers())),
        ])
    }
}

/// Current resident set size in bytes (Linux `/proc/self/status`), and the
/// peak (`VmHWM`). Returns 0 if unavailable (non-Linux).
pub fn current_rss_bytes() -> u64 {
    read_proc_status_kb("VmRSS:") * 1024
}

/// Peak RSS (high-water mark) in bytes.
pub fn peak_rss_bytes() -> u64 {
    read_proc_status_kb("VmHWM:") * 1024
}

fn read_proc_status_kb(field: &str) -> u64 {
    let Ok(text) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(field) {
            let kb: u64 = rest
                .trim()
                .trim_end_matches(" kB")
                .trim()
                .parse()
                .unwrap_or(0);
            return kb;
        }
    }
    0
}

/// Process CPU time (user+sys) so far, from `/proc/self/stat` (Linux).
pub fn process_cpu_time() -> Duration {
    let Ok(text) = std::fs::read_to_string("/proc/self/stat") else {
        return Duration::ZERO;
    };
    // Fields 14 (utime) and 15 (stime) in clock ticks, after the comm field
    // which can contain spaces — skip past the closing paren.
    let Some(rest) = text.rsplit_once(')').map(|(_, r)| r) else {
        return Duration::ZERO;
    };
    let fields: Vec<&str> = rest.split_whitespace().collect();
    if fields.len() < 13 {
        return Duration::ZERO;
    }
    let utime: u64 = fields[11].parse().unwrap_or(0);
    let stime: u64 = fields[12].parse().unwrap_or(0);
    let ticks_per_sec = 100u64; // Linux USER_HZ is 100 on all mainstream builds
    Duration::from_nanos((utime + stime) * (1_000_000_000 / ticks_per_sec))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_monotone() {
        let mut prev = 0;
        for ns in [1u64, 2, 5, 10, 100, 1_000, 10_000, 1_000_000, 1 << 40] {
            let b = bucket_index(ns);
            assert!(b >= prev, "bucket not monotone at {ns}");
            prev = b;
            assert!(bucket_lower_bound(b) <= ns, "lower bound above value at {ns}");
        }
    }

    #[test]
    fn bucket_relative_error_bounded() {
        for ns in (0..200).map(|i| 1u64 << (i % 40)).chain(1..1000) {
            let b = bucket_index(ns);
            let lo = bucket_lower_bound(b);
            let hi = bucket_lower_bound(b + 1);
            assert!(lo <= ns && ns < hi, "ns={ns} not in [{lo},{hi})");
            if ns > 16 {
                let err = (hi - lo) as f64 / ns as f64;
                assert!(err <= 0.15, "relative error {err} at {ns}");
            }
        }
    }

    #[test]
    fn quantiles_ordered() {
        let h = LatencyHistogram::new();
        let mut rng = crate::util::rng::Rng::seeded(11);
        for _ in 0..10_000 {
            h.record_ns(1_000 + rng.below(1_000_000));
        }
        let s = h.summary();
        assert_eq!(s.count, 10_000);
        assert!(s.p50_ns <= s.p90_ns && s.p90_ns <= s.p95_ns && s.p95_ns <= s.p99_ns);
        assert!(s.p99_ns <= s.max_ns);
        assert!(s.mean_ns > 1_000.0);
    }

    #[test]
    fn quantile_accuracy_on_constant() {
        let h = LatencyHistogram::new();
        for _ in 0..1000 {
            h.record_ns(123_456);
        }
        let p50 = h.quantile_ns(0.5);
        let err = (p50 as f64 - 123_456.0).abs() / 123_456.0;
        assert!(err < 0.15, "p50={p50}");
    }

    #[test]
    fn empty_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn reset_clears() {
        let h = LatencyHistogram::new();
        h.record_ns(5);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.max_ns(), 0);
    }

    #[test]
    fn replication_gauges_track_lag_and_role() {
        let g = ReplicationGauges::default();
        assert_eq!(g.role(), ReplicationRole::Single);
        assert_eq!(g.apply_staleness_ms(), 0.0, "staleness before any apply");
        g.set_role(ReplicationRole::Follower);
        g.set_leader_hint(Some("127.0.0.1:7777".into()));
        g.note_received(5);
        g.note_received(8);
        g.note_applied(5);
        assert_eq!(g.last_received_seq(), 8);
        assert_eq!(g.last_applied_seq(), 5);
        assert_eq!(g.lag_records(), 3);
        assert!(g.apply_staleness_ms() >= 0.0);
        let j = g.to_json(10);
        assert_eq!(j.get("role").as_str(), Some("follower"));
        assert_eq!(j.get("leader").as_str(), Some("127.0.0.1:7777"));
        assert_eq!(j.get("wal_last_seq").as_u64(), Some(10));
        // Lag vs the freshest known seq: max(wal 10, received 8) - applied 5.
        assert_eq!(j.get("replication_lag_records").as_u64(), Some(5));
        // Stale gauges never go backwards.
        g.note_applied(4);
        assert_eq!(g.last_applied_seq(), 5);
        // Leaders report zero lag regardless of gauges.
        g.set_role(ReplicationRole::Leader);
        g.note_shipped(7);
        g.subscriber_connected();
        let j = g.to_json(12);
        assert_eq!(j.get("replication_lag_records").as_u64(), Some(0));
        assert_eq!(j.get("records_shipped").as_u64(), Some(7));
        assert_eq!(j.get("subscribers").as_u64(), Some(1));
        g.subscriber_disconnected();
        assert_eq!(g.subscribers(), 0);
    }

    #[test]
    fn ack_timeouts_attributed_per_subscriber() {
        let g = ReplicationGauges::default();
        g.note_ack_timeout(&[3]);
        g.note_ack_timeout(&[3, 7]);
        g.note_ack_timeout(&[]); // timed out with no identifiable laggard
        assert_eq!(g.ack_timeouts_for(3), 2);
        assert_eq!(g.ack_timeouts_for(7), 1);
        assert_eq!(g.ack_timeouts_for(9), 0);
        let j = g.to_json(0);
        assert_eq!(j.get("ack_timeouts").as_u64(), Some(3));
        let by_sub = j.get("ack_timeouts_by_subscriber");
        assert_eq!(by_sub.get("3").as_u64(), Some(2));
        assert_eq!(by_sub.get("7").as_u64(), Some(1));
        // A closed stream's row is pruned (subscriber churn must not
        // grow the map forever); the aggregate count survives.
        g.forget_subscriber(3);
        assert_eq!(g.ack_timeouts_for(3), 0);
        assert_eq!(g.ack_timeouts_for(7), 1);
        let j = g.to_json(0);
        assert_eq!(j.get("ack_timeouts").as_u64(), Some(3));
        assert!(j.get("ack_timeouts_by_subscriber").get("3").as_u64().is_none());
    }

    #[test]
    fn fault_gauges_count_by_kind() {
        // The gauges are process-global and other tests may bump them
        // concurrently, so assert on deltas with ≥.
        let f = faults();
        let enospc0 = f.injected_total();
        let retries0 = f.backoff_retries();
        f.note_injected("enospc");
        f.note_injected("torn");
        f.note_injected("crash");
        f.note_backoff_retry();
        f.note_circuit_open();
        assert!(f.injected_total() >= enospc0 + 3);
        assert!(f.backoff_retries() >= retries0 + 1);
        assert!(f.circuit_open_windows() >= 1);
        let j = f.to_json();
        assert!(j.get("injected").get("enospc").as_u64().unwrap_or(0) >= 1);
        assert!(j.get("injected").get("torn").as_u64().unwrap_or(0) >= 1);
        assert!(j.get("backoff_retries").as_u64().unwrap_or(0) >= 1);
        assert!(j.get("circuit_open_windows").as_u64().unwrap_or(0) >= 1);
    }

    #[test]
    fn monotonic_ms_is_monotone() {
        let a = monotonic_ms();
        std::thread::sleep(Duration::from_millis(2));
        let b = monotonic_ms();
        assert!(b >= a);
        assert!(b.saturating_sub(a) >= 1, "clock did not advance: {a}..{b}");
    }

    #[test]
    fn rss_probe_positive_on_linux() {
        if std::path::Path::new("/proc/self/status").exists() {
            assert!(current_rss_bytes() > 0);
            assert!(peak_rss_bytes() >= current_rss_bytes() / 2);
        }
    }

    #[test]
    fn cpu_time_monotone() {
        let a = process_cpu_time();
        // Burn a little CPU.
        let mut x = 0u64;
        for i in 0..2_000_000u64 {
            x = x.wrapping_add(i * i);
        }
        std::hint::black_box(x);
        let b = process_cpu_time();
        assert!(b >= a);
    }
}
