//! MinHash LSH for token-set features.
//!
//! Band `b` combines `rows` per-row minima (min over `mix2(row_seed, token)`)
//! into one signature; collision probability in a band is `J^rows` for
//! Jaccard similarity `J` — the standard minhash banding scheme.
//!
//! Empty token sets produce no buckets (a point with no tokens cannot be
//! similar to anything through this channel).

use crate::util::hash::{mix2, mix3};

/// MinHash bucketer for one token channel.
pub struct MinHash {
    bands: usize,
    rows: usize,
    seed: u64,
}

impl MinHash {
    pub fn new(bands: usize, rows: usize, seed: u64) -> MinHash {
        assert!(bands > 0 && rows > 0);
        MinHash { bands, rows, seed }
    }

    /// Append bucket IDs (one per band) for a token set.
    pub fn buckets_into(&self, tokens: &[u64], out: &mut Vec<u64>) {
        if tokens.is_empty() {
            return;
        }
        for band in 0..self.bands {
            let mut sig = 0u64;
            for row in 0..self.rows {
                let row_seed = mix3(self.seed, band as u64, row as u64);
                let m = tokens
                    .iter()
                    .map(|&t| mix2(row_seed, t))
                    .min()
                    .unwrap();
                // Combine row minima order-dependently.
                sig = mix2(sig, m);
            }
            out.push(mix3(self.seed, 0x6d68 + band as u64, sig));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn buckets(m: &MinHash, tokens: &[u64]) -> Vec<u64> {
        let mut out = Vec::new();
        m.buckets_into(tokens, &mut out);
        out
    }

    #[test]
    fn empty_tokens_no_buckets() {
        let m = MinHash::new(4, 2, 1);
        assert!(buckets(&m, &[]).is_empty());
    }

    #[test]
    fn one_bucket_per_band_and_deterministic() {
        let m = MinHash::new(6, 2, 9);
        let b1 = buckets(&m, &[1, 2, 3]);
        let b2 = buckets(&m, &[3, 2, 1]); // order-invariant (set semantics)
        assert_eq!(b1.len(), 6);
        assert_eq!(b1, b2);
    }

    #[test]
    fn identical_sets_collide_fully() {
        let m = MinHash::new(8, 3, 5);
        assert_eq!(buckets(&m, &[10, 20, 30]), buckets(&m, &[10, 20, 30]));
    }

    #[test]
    fn jaccard_monotonicity() {
        // Statistically: higher Jaccard ⇒ more shared bands.
        let m = MinHash::new(64, 1, 13);
        let mut rng = Rng::seeded(3);
        let mut shared_hi = 0usize;
        let mut shared_lo = 0usize;
        for _ in 0..20 {
            let base: Vec<u64> = (0..40).map(|_| rng.below(10_000)).collect();
            // hi: 90% overlap; lo: 10% overlap.
            let mut hi = base[..36].to_vec();
            hi.extend((0..4).map(|_| rng.below(10_000) + 20_000));
            let mut lo = base[..4].to_vec();
            lo.extend((0..36).map(|_| rng.below(10_000) + 20_000));
            let bb = buckets(&m, &base);
            let bh = buckets(&m, &hi);
            let bl = buckets(&m, &lo);
            shared_hi += bb.iter().zip(&bh).filter(|(a, b)| a == b).count();
            shared_lo += bb.iter().zip(&bl).filter(|(a, b)| a == b).count();
        }
        assert!(
            shared_hi > shared_lo * 2,
            "minhash not similarity sensitive: hi={shared_hi} lo={shared_lo}"
        );
    }

    #[test]
    fn rows_sharpen_threshold() {
        // With more rows per band, low-Jaccard pairs collide less.
        let mut rng = Rng::seeded(4);
        let m1 = MinHash::new(32, 1, 7);
        let m4 = MinHash::new(32, 4, 7);
        let (mut c1, mut c4) = (0usize, 0usize);
        for _ in 0..30 {
            let a: Vec<u64> = (0..20).map(|_| rng.below(1000)).collect();
            let mut b = a[..10].to_vec(); // ~0.33 jaccard
            b.extend((0..10).map(|_| 5000 + rng.below(1000)));
            c1 += buckets(&m1, &a)
                .iter()
                .zip(buckets(&m1, &b).iter())
                .filter(|(x, y)| x == y)
                .count();
            c4 += buckets(&m4, &a)
                .iter()
                .zip(buckets(&m4, &b).iter())
                .filter(|(x, y)| x == y)
                .count();
        }
        assert!(c1 > c4, "rows did not sharpen: rows1={c1} rows4={c4}");
    }
}
