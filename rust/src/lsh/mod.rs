//! Locality-Sensitive Hashing: features → bucket IDs.
//!
//! Grale computes, for each point, a list of bucket IDs via LSH; points
//! sharing a bucket ID become *scoring pairs* (§4 of the paper). Dynamic GUS
//! reuses the same bucket IDs as the non-zero dimensions of the sparse
//! embedding (§4.1). The paper deliberately leaves the bucketing algorithm
//! pluggable ("these buckets can be done via any other algorithm as well");
//! we implement the standard family per feature kind:
//!
//! - dense embeddings → [`hyperplane`] sign-random-projection bands,
//! - token sets → [`minhash`] bands or direct per-token buckets,
//! - scalars → [`scalar`] overlapping quantization.
//!
//! Bucket IDs are 64-bit hashes namespaced by (channel, band) so different
//! channels can never collide into the same bucket except by hash collision
//! (~2⁻⁶⁴).

pub mod hyperplane;
pub mod minhash;
pub mod scalar;

use crate::features::{FeatureValue, Point, Schema};
use crate::util::hash::{mix2, mix3};

pub use hyperplane::HyperplaneLsh;
pub use minhash::MinHash;
pub use scalar::ScalarQuantizer;

/// Per-channel bucketing configuration.
#[derive(Debug, Clone, PartialEq)]
pub enum ChannelLshConfig {
    /// Sign-random-projection bands for dense channels:
    /// `bands` independent bucket IDs per point, each from `bits` hyperplanes.
    Hyperplane { bands: usize, bits: usize },
    /// MinHash bands for token channels: `bands` bucket IDs, each the min of
    /// `rows` per-row minima combined (rows=1 ⇒ plain minhash).
    MinHash { bands: usize, rows: usize },
    /// Each token becomes its own bucket ID (good when tokens are already
    /// strong similarity signals, e.g. co-purchased product ids).
    DirectTokens,
    /// Overlapping scalar quantization: `offsets` shifted grids of `width`.
    Quantize { width: f32, offsets: usize },
    /// Channel does not contribute buckets (model-only channel).
    Skip,
}

/// Full bucketer for a schema: one config per channel.
pub struct Bucketer {
    schema: Schema,
    seed: u64,
    channels: Vec<ChannelBucketer>,
}

enum ChannelBucketer {
    Hyperplane(HyperplaneLsh),
    MinHash(MinHash),
    DirectTokens { seed: u64 },
    Quantize(ScalarQuantizer),
    Skip,
}

impl Bucketer {
    /// Build a bucketer. `configs` must have one entry per schema channel.
    pub fn new(schema: &Schema, configs: &[ChannelLshConfig], seed: u64) -> Bucketer {
        assert_eq!(
            configs.len(),
            schema.channels.len(),
            "one LSH config per channel"
        );
        let channels = configs
            .iter()
            .enumerate()
            .map(|(ch, cfg)| {
                let ch_seed = mix2(seed, ch as u64);
                match cfg {
                    ChannelLshConfig::Hyperplane { bands, bits } => ChannelBucketer::Hyperplane(
                        HyperplaneLsh::new(schema.channels[ch].dim, *bands, *bits, ch_seed),
                    ),
                    ChannelLshConfig::MinHash { bands, rows } => {
                        ChannelBucketer::MinHash(MinHash::new(*bands, *rows, ch_seed))
                    }
                    ChannelLshConfig::DirectTokens => {
                        ChannelBucketer::DirectTokens { seed: ch_seed }
                    }
                    ChannelLshConfig::Quantize { width, offsets } => ChannelBucketer::Quantize(
                        ScalarQuantizer::new(*width, *offsets, ch_seed),
                    ),
                    ChannelLshConfig::Skip => ChannelBucketer::Skip,
                }
            })
            .collect();
        Bucketer { schema: schema.clone(), seed, channels }
    }

    /// Default configs for the paper's two dataset shapes.
    pub fn default_configs(schema: &Schema) -> Vec<ChannelLshConfig> {
        schema
            .channels
            .iter()
            .map(|c| match c.kind {
                crate::features::FeatureKind::Dense => {
                    ChannelLshConfig::Hyperplane { bands: 16, bits: 12 }
                }
                crate::features::FeatureKind::Tokens => ChannelLshConfig::DirectTokens,
                crate::features::FeatureKind::Scalar => {
                    ChannelLshConfig::Quantize { width: 2.0, offsets: 2 }
                }
            })
            .collect()
    }

    /// Convenience: bucketer with default configs.
    pub fn with_defaults(schema: &Schema, seed: u64) -> Bucketer {
        let configs = Self::default_configs(schema);
        Bucketer::new(schema, &configs, seed)
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Compute the point's bucket IDs (sorted, deduplicated).
    ///
    /// This is the hot path for both mutations and queries — it runs on
    /// purely local information, no global state (a hard requirement from
    /// §3.2: the Embedding Generator is on the critical path).
    pub fn buckets(&self, p: &Point) -> Vec<u64> {
        let mut out = Vec::with_capacity(32);
        self.buckets_into(p, &mut out);
        out
    }

    /// `buckets` with a caller-provided buffer (hot path, no allocation).
    pub fn buckets_into(&self, p: &Point, out: &mut Vec<u64>) {
        out.clear();
        for (ch, bucketer) in self.channels.iter().enumerate() {
            match (bucketer, &p.features[ch]) {
                (ChannelBucketer::Hyperplane(h), FeatureValue::Dense(v)) => {
                    h.buckets_into(v, out);
                }
                (ChannelBucketer::MinHash(m), FeatureValue::Tokens(t)) => {
                    m.buckets_into(t, out);
                }
                (ChannelBucketer::DirectTokens { seed }, FeatureValue::Tokens(t)) => {
                    for &tok in t {
                        out.push(mix3(*seed, 0xd17ec7, tok));
                    }
                }
                (ChannelBucketer::Quantize(q), FeatureValue::Scalar(x)) => {
                    q.buckets_into(*x, out);
                }
                (ChannelBucketer::Skip, _) => {}
                (_, f) => panic!(
                    "channel {ch}: LSH config does not match feature kind {:?}",
                    f.kind()
                ),
            }
        }
        out.sort_unstable();
        out.dedup();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::{FeatureValue, Point, Schema};
    use crate::util::rng::Rng;

    fn schema3() -> Schema {
        let mut s = Schema::arxiv_like(16);
        s.channels.push(crate::features::ChannelSchema {
            name: "tags".to_string(),
            kind: crate::features::FeatureKind::Tokens,
            dim: 0,
        });
        s
    }

    fn point3(rng: &mut Rng) -> Point {
        Point::new(
            rng.below(1 << 40),
            vec![
                FeatureValue::Dense(rng.normal_vec_f32(16)),
                FeatureValue::Scalar(2000.0 + rng.below(30) as f32),
                FeatureValue::Tokens((0..rng.below_usize(6)).map(|_| rng.below(100)).collect()),
            ],
        )
    }

    #[test]
    fn deterministic() {
        let s = schema3();
        let cfg = vec![
            ChannelLshConfig::Hyperplane { bands: 4, bits: 8 },
            ChannelLshConfig::Quantize { width: 2.0, offsets: 2 },
            ChannelLshConfig::DirectTokens,
        ];
        let b1 = Bucketer::new(&s, &cfg, 99);
        let b2 = Bucketer::new(&s, &cfg, 99);
        let mut rng = Rng::seeded(1);
        for _ in 0..20 {
            let p = point3(&mut rng);
            assert_eq!(b1.buckets(&p), b2.buckets(&p));
        }
        // Different seed ⇒ (almost surely) different buckets.
        let b3 = Bucketer::new(&s, &cfg, 100);
        let p = point3(&mut rng);
        assert_ne!(b1.buckets(&p), b3.buckets(&p));
    }

    #[test]
    fn sorted_dedup_output() {
        let s = schema3();
        let b = Bucketer::with_defaults(&s, 7);
        let mut rng = Rng::seeded(2);
        for _ in 0..20 {
            let p = point3(&mut rng);
            let buckets = b.buckets(&p);
            assert!(buckets.windows(2).all(|w| w[0] < w[1]), "unsorted/dup");
        }
    }

    #[test]
    fn identical_points_share_all_buckets() {
        let s = schema3();
        let b = Bucketer::with_defaults(&s, 7);
        let mut rng = Rng::seeded(3);
        let p = point3(&mut rng);
        let mut q = p.clone();
        q.id = p.id + 1; // id does not affect buckets
        assert_eq!(b.buckets(&p), b.buckets(&q));
    }

    #[test]
    fn similar_points_share_more_buckets_than_dissimilar() {
        // The LSH property, statistically: near-duplicates collide in many
        // bands; random pairs rarely do.
        let s = Schema::arxiv_like(32);
        let b = Bucketer::with_defaults(&s, 11);
        let mut rng = Rng::seeded(4);
        let mut sim_shared = 0usize;
        let mut rand_shared = 0usize;
        for _ in 0..50 {
            let base: Vec<f32> = rng.normal_vec_f32(32);
            let near: Vec<f32> = base.iter().map(|x| x + 0.05 * rng.normal() as f32).collect();
            let far: Vec<f32> = rng.normal_vec_f32(32);
            let mk = |v: Vec<f32>| {
                Point::new(0, vec![FeatureValue::Dense(v), FeatureValue::Scalar(2020.0)])
            };
            let pb = b.buckets(&mk(base));
            let pn = b.buckets(&mk(near));
            let pf = b.buckets(&mk(far));
            sim_shared += pb.iter().filter(|x| pn.binary_search(x).is_ok()).count();
            rand_shared += pb.iter().filter(|x| pf.binary_search(x).is_ok()).count();
        }
        assert!(
            sim_shared > rand_shared * 3,
            "LSH not locality sensitive: near={sim_shared} far={rand_shared}"
        );
    }

    #[test]
    fn channels_do_not_collide() {
        // Two channels with identical content must produce distinct buckets.
        let s = Schema {
            name: "twin".into(),
            channels: vec![
                crate::features::ChannelSchema {
                    name: "a".into(),
                    kind: crate::features::FeatureKind::Tokens,
                    dim: 0,
                },
                crate::features::ChannelSchema {
                    name: "b".into(),
                    kind: crate::features::FeatureKind::Tokens,
                    dim: 0,
                },
            ],
        };
        let cfg = vec![ChannelLshConfig::DirectTokens, ChannelLshConfig::DirectTokens];
        let b = Bucketer::new(&s, &cfg, 5);
        let p = Point::new(
            1,
            vec![
                FeatureValue::Tokens(vec![42]),
                FeatureValue::Tokens(vec![42]),
            ],
        );
        assert_eq!(b.buckets(&p).len(), 2, "channel namespacing failed");
    }

    #[test]
    fn skip_channel_contributes_nothing() {
        let s = Schema::arxiv_like(8);
        let cfg = vec![
            ChannelLshConfig::Skip,
            ChannelLshConfig::Quantize { width: 1.0, offsets: 1 },
        ];
        let b = Bucketer::new(&s, &cfg, 5);
        let p = Point::new(
            1,
            vec![FeatureValue::Dense(vec![1.0; 8]), FeatureValue::Scalar(2020.0)],
        );
        assert_eq!(b.buckets(&p).len(), 1);
    }
}
