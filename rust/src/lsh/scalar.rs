//! Overlapping scalar quantization for scalar features.
//!
//! A scalar (e.g. publication year) is mapped to `offsets` shifted grids of
//! cell `width`: grid `o` buckets `x` at `floor(x/width + o/offsets)`. Two
//! scalars within `width * (1 - 1/offsets)` of each other are guaranteed to
//! share at least one grid cell for some shift; values far apart share none.
//! This is the 1-d analogue of Grale's bucketing for ordinal features.

use crate::util::hash::mix3;

/// Overlapping quantizer for one scalar channel.
pub struct ScalarQuantizer {
    width: f32,
    offsets: usize,
    seed: u64,
}

impl ScalarQuantizer {
    pub fn new(width: f32, offsets: usize, seed: u64) -> ScalarQuantizer {
        assert!(width > 0.0 && offsets > 0);
        ScalarQuantizer { width, offsets, seed }
    }

    /// Append bucket IDs (one per shifted grid).
    pub fn buckets_into(&self, x: f32, out: &mut Vec<u64>) {
        for o in 0..self.offsets {
            let shift = o as f32 / self.offsets as f32;
            let cell = (x / self.width + shift).floor() as i64;
            out.push(mix3(self.seed, o as u64, cell as u64));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn buckets(q: &ScalarQuantizer, x: f32) -> Vec<u64> {
        let mut out = Vec::new();
        q.buckets_into(x, &mut out);
        out
    }

    #[test]
    fn one_bucket_per_offset() {
        let q = ScalarQuantizer::new(2.0, 3, 1);
        assert_eq!(buckets(&q, 5.0).len(), 3);
    }

    #[test]
    fn equal_values_share_all() {
        let q = ScalarQuantizer::new(2.0, 2, 5);
        assert_eq!(buckets(&q, 2020.0), buckets(&q, 2020.0));
    }

    #[test]
    fn close_values_share_some_far_share_none() {
        let q = ScalarQuantizer::new(2.0, 2, 5);
        let a = buckets(&q, 2020.0);
        let close = buckets(&q, 2020.6); // within width*(1-1/2)=1.0
        let far = buckets(&q, 2030.0);
        let shared_close = a.iter().filter(|x| close.contains(x)).count();
        let shared_far = a.iter().filter(|x| far.contains(x)).count();
        assert!(shared_close >= 1, "close values must share a bucket");
        assert_eq!(shared_far, 0);
    }

    #[test]
    fn negative_values_work() {
        let q = ScalarQuantizer::new(1.0, 2, 5);
        let a = buckets(&q, -3.2);
        let b = buckets(&q, -3.2);
        assert_eq!(a, b);
        assert_ne!(buckets(&q, -3.2), buckets(&q, 3.2));
    }

    #[test]
    fn guarantee_threshold() {
        // Any pair within width*(1-1/offsets) shares >= 1 bucket.
        let q = ScalarQuantizer::new(4.0, 4, 9);
        let thresh = 4.0 * (1.0 - 0.25);
        for i in 0..200 {
            let x = -50.0 + i as f32 * 0.5;
            let y = x + thresh * 0.99;
            let bx = buckets(&q, x);
            let by = buckets(&q, y);
            assert!(
                bx.iter().any(|b| by.contains(b)),
                "no shared bucket for x={x}, y={y}"
            );
        }
    }
}
