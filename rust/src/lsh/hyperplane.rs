//! Sign-random-projection (hyperplane) LSH for dense embeddings.
//!
//! Band `b` owns `bits` random Gaussian hyperplanes; a point's signature in
//! band `b` packs the signs of the projections. Two points collide in a band
//! with probability `(1 - θ/π)^bits` where θ is the angle between them — the
//! classic SimHash guarantee, which is what makes shared bucket IDs a good
//! candidate-neighbor signal.

use crate::util::hash::mix3;
use crate::util::rng::Rng;

/// Hyperplane LSH for one dense channel.
pub struct HyperplaneLsh {
    dim: usize,
    bands: usize,
    bits: usize,
    /// Row-major `[bands * bits][dim]` hyperplane normals.
    planes: Vec<f32>,
    seed: u64,
}

impl HyperplaneLsh {
    pub fn new(dim: usize, bands: usize, bits: usize, seed: u64) -> HyperplaneLsh {
        assert!(dim > 0 && bands > 0 && bits > 0 && bits <= 64);
        let mut rng = Rng::seeded(seed ^ 0x9e3779b97f4a7c15);
        let planes = rng.normal_vec_f32(bands * bits * dim);
        HyperplaneLsh { dim, bands, bits, planes, seed }
    }

    pub fn bands(&self) -> usize {
        self.bands
    }

    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Band signature: packed projection signs.
    fn signature(&self, band: usize, v: &[f32]) -> u64 {
        let mut sig = 0u64;
        let base = band * self.bits * self.dim;
        for bit in 0..self.bits {
            let row = &self.planes[base + bit * self.dim..base + (bit + 1) * self.dim];
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(v) {
                acc += a * b;
            }
            if acc >= 0.0 {
                sig |= 1 << bit;
            }
        }
        sig
    }

    /// Append this channel's bucket IDs (one per band).
    pub fn buckets_into(&self, v: &[f32], out: &mut Vec<u64>) {
        assert_eq!(v.len(), self.dim, "dense dim mismatch");
        for band in 0..self.bands {
            let sig = self.signature(band, v);
            out.push(mix3(self.seed, band as u64, sig));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_bucket_per_band() {
        let h = HyperplaneLsh::new(8, 5, 10, 1);
        let mut out = Vec::new();
        h.buckets_into(&[0.3; 8], &mut out);
        assert_eq!(out.len(), 5);
    }

    #[test]
    fn deterministic_across_instances() {
        let h1 = HyperplaneLsh::new(8, 3, 6, 42);
        let h2 = HyperplaneLsh::new(8, 3, 6, 42);
        let v: Vec<f32> = (0..8).map(|i| (i as f32) - 3.5).collect();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        h1.buckets_into(&v, &mut a);
        h2.buckets_into(&v, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn scale_invariant() {
        // Hyperplane signs ignore magnitude: v and 3v share all buckets.
        let h = HyperplaneLsh::new(16, 8, 8, 7);
        let mut rng = Rng::seeded(1);
        let v = rng.normal_vec_f32(16);
        let v3: Vec<f32> = v.iter().map(|x| x * 3.0).collect();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        h.buckets_into(&v, &mut a);
        h.buckets_into(&v3, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn collision_probability_decreases_with_angle() {
        let h = HyperplaneLsh::new(32, 64, 4, 3);
        let mut rng = Rng::seeded(2);
        let count_shared = |noise: f32, rng: &mut Rng| -> usize {
            let mut shared = 0;
            for _ in 0..20 {
                let v = rng.normal_vec_f32(32);
                let w: Vec<f32> =
                    v.iter().map(|x| x + noise * rng.normal() as f32).collect();
                let (mut a, mut b) = (Vec::new(), Vec::new());
                h.buckets_into(&v, &mut a);
                h.buckets_into(&w, &mut b);
                a.sort_unstable();
                shared += b.iter().filter(|x| a.binary_search(x).is_ok()).count();
            }
            shared
        };
        let near = count_shared(0.05, &mut rng);
        let mid = count_shared(0.5, &mut rng);
        let far = count_shared(5.0, &mut rng);
        assert!(near > mid && mid > far, "near={near} mid={mid} far={far}");
    }

    #[test]
    #[should_panic]
    fn wrong_dim_panics() {
        let h = HyperplaneLsh::new(8, 1, 4, 0);
        let mut out = Vec::new();
        h.buckets_into(&[1.0; 7], &mut out);
    }
}
