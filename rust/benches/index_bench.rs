//! Index micro-benchmarks: the ScaNN-substitute's retrieval hot path.
//!
//! These isolate step 3 of the Neighborhood RPC (candidate retrieval) from
//! embedding and scoring, across ScaNN-NN and corpus scale — the knobs
//! Fig. 9 shows dominate latency.

use dynamic_gus::bench::Bencher;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::embed::EmbeddingGenerator;
use dynamic_gus::index::{QueryParams, QueryScratch, SparseAnn};
use dynamic_gus::lsh::Bucketer;
use dynamic_gus::sparse::SparseVec;

fn build(n: usize, seed: u64) -> (SparseAnn, Vec<SparseVec>) {
    let ds = SyntheticConfig::arxiv_like(n, seed).generate();
    let generator =
        EmbeddingGenerator::plain(Bucketer::with_defaults(&ds.schema, 0xe7a1));
    let mut index = SparseAnn::new();
    let mut embeddings = Vec::with_capacity(n);
    for p in &ds.points {
        let e = generator.embed(p);
        index.upsert(p.id, e.clone());
        embeddings.push(e);
    }
    (index, embeddings)
}

fn main() {
    let mut b = Bencher::new();
    for &n in &[5_000usize, 20_000] {
        let (mut index, embeddings) = build(n, 0xb1);
        let mut scratch = QueryScratch::default();
        let mut qi = 0usize;
        for &k in &[10usize, 100, 1000] {
            b.bench(&format!("index/top_k/n={n}/k={k}"), || {
                qi = (qi + 7919) % embeddings.len();
                index.top_k(
                    &embeddings[qi],
                    k,
                    QueryParams { exclude: Some(qi as u64), max_postings: 0 },
                    &mut scratch,
                )
            });
        }
        b.bench(&format!("index/threshold_all_negative/n={n}"), || {
            qi = (qi + 7919) % embeddings.len();
            index.threshold(
                &embeddings[qi],
                -f32::MIN_POSITIVE,
                QueryParams::default(),
                &mut scratch,
            )
        });
        // Mutation path.
        let mut victim = 0u64;
        b.bench(&format!("index/upsert_remove_cycle/n={n}"), || {
            victim = (victim + 13) % n as u64;
            let e = embeddings[victim as usize].clone();
            index.remove(victim);
            index.upsert(victim, e)
        });
    }
    b.dump_json("index_bench");
    b.dump_repo_summary("index_bench", Vec::new());
}
