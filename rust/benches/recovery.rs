//! Restart cost: recovery latency vs WAL length.
//!
//! The durability design's claim is that restart cost is O(checkpoint
//! delta), not O(corpus): `recover` restores the latest checkpoint and
//! replays only the WAL tail. This bench prepares one checkpoint of a
//! fixed corpus plus WAL tails of increasing length and measures
//! end-to-end `wal::recover` latency for each, alongside the WAL append
//! cost per fsync policy (the price paid on the mutation path).
//!
//! Expected shape: `recover/delta=0` ≈ the pure snapshot restore;
//! each added WAL record costs roughly one embed+upsert on top.

use dynamic_gus::bench::Bencher;
use dynamic_gus::config::{FsyncPolicy, GusConfig, ScorerKind};
use dynamic_gus::coordinator::{snapshot, wal, DynamicGus};
use dynamic_gus::data::synthetic::SyntheticConfig;

fn bench_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("gus-recovery-bench").join(name);
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn main() {
    let mut b = Bencher::new();
    let corpus = 2_000usize;
    let ds = SyntheticConfig::arxiv_like(corpus + 1_024, 0xeec0).generate();
    let cfg = GusConfig {
        scorer: ScorerKind::Native,
        filter_p: 10.0,
        fsync: FsyncPolicy::Never,
        ..GusConfig::default()
    };

    // One durable dir per WAL tail length: checkpoint of `corpus` points,
    // then `delta` uncheckpointed mutations.
    for delta in [0usize, 64, 256, 1024] {
        let dir = bench_dir(&format!("delta-{delta}"));
        let gus =
            DynamicGus::bootstrap(ds.schema.clone(), cfg.clone(), &ds.points[..corpus], 8)
                .unwrap();
        wal::init_fresh(&gus, &dir).unwrap();
        for p in &ds.points[corpus..corpus + delta] {
            gus.insert(p.clone()).unwrap();
        }
        drop(gus); // crash without checkpoint: the delta lives in the WAL
        b.bench(&format!("recover/corpus={corpus}/delta={delta}"), || {
            let rec = wal::recover(&dir, 8).unwrap();
            assert_eq!(rec.replayed, delta);
            rec.gus.len()
        });
    }

    // Baseline: pure snapshot restore of the same corpus (what `recover`
    // does before any replay).
    {
        let dir = bench_dir("snapshot-only");
        let gus =
            DynamicGus::bootstrap(ds.schema.clone(), cfg.clone(), &ds.points[..corpus], 8)
                .unwrap();
        snapshot::save(&gus, &dir).unwrap();
        drop(gus);
        b.bench(&format!("restore/snapshot-only/corpus={corpus}"), || {
            snapshot::restore(&dir, 8).unwrap().len()
        });
    }

    // The other side of the ledger: what logging costs the mutation path
    // at each fsync policy (insert latency with durability on vs off).
    for (name, policy) in [
        ("never", FsyncPolicy::Never),
        ("every_n:32", FsyncPolicy::EveryN(32)),
        ("always", FsyncPolicy::Always),
    ] {
        let dir = bench_dir(&format!("append-{name}"));
        let gus = DynamicGus::bootstrap(
            ds.schema.clone(),
            GusConfig { fsync: policy, ..cfg.clone() },
            &ds.points[..corpus],
            8,
        )
        .unwrap();
        wal::init_fresh(&gus, &dir).unwrap();
        let holdout = &ds.points[corpus..];
        let mut i = 0usize;
        b.bench(&format!("insert/wal/fsync={name}"), || {
            let p = holdout[i % holdout.len()].clone();
            i += 1;
            gus.insert(p).unwrap()
        });
    }
    {
        let gus =
            DynamicGus::bootstrap(ds.schema.clone(), cfg.clone(), &ds.points[..corpus], 8)
                .unwrap();
        let holdout = &ds.points[corpus..];
        let mut i = 0usize;
        b.bench("insert/no-wal", || {
            let p = holdout[i % holdout.len()].clone();
            i += 1;
            gus.insert(p).unwrap()
        });
    }

    b.dump_json("recovery");
}
