//! Embedding-generation benchmarks: §3.2's latency-critical component
//! ("it is crucial for this component to have a very low latency").
//!
//! The paper claims embedding computation takes "a few milliseconds" and
//! is negligible; these benches verify that for both schemas, with and
//! without IDF/filter tables.

use dynamic_gus::bench::Bencher;
use dynamic_gus::config::GusConfig;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::embed::EmbeddingGenerator;
use dynamic_gus::lsh::Bucketer;
use dynamic_gus::preprocess;

fn main() {
    let mut b = Bencher::new();
    for (name, ds) in [
        ("arxiv_like", SyntheticConfig::arxiv_like(5_000, 0xe1).generate()),
        ("products_like", SyntheticConfig::products_like(5_000, 0xe2).generate()),
    ] {
        let bucketer = Bucketer::with_defaults(&ds.schema, 0xe7a1);
        let plain = EmbeddingGenerator::plain(Bucketer::with_defaults(&ds.schema, 0xe7a1));
        let mut i = 0usize;
        b.bench(&format!("embed/plain/{name}"), || {
            i = (i + 1) % ds.points.len();
            plain.embed(&ds.points[i])
        });

        // With IDF + filter tables (the production configuration).
        let cfg = GusConfig { idf_s: 1_000_000, filter_p: 10.0, ..GusConfig::default() };
        let pre = preprocess::preprocess(&bucketer, &ds.points, &cfg, 8);
        let full = preprocess::build_generator(
            Bucketer::with_defaults(&ds.schema, 0xe7a1),
            &pre,
        );
        b.bench(&format!("embed/idf+filter/{name}"), || {
            i = (i + 1) % ds.points.len();
            full.embed(&ds.points[i])
        });

        // Bucketing alone (the LSH cost).
        let mut buf = Vec::new();
        b.bench(&format!("embed/buckets_only/{name}"), || {
            i = (i + 1) % ds.points.len();
            bucketer.buckets_into(&ds.points[i], &mut buf);
            buf.len()
        });

        // Offline preprocessing throughput (per 5k corpus).
        b.bench(&format!("preprocess/5k_corpus/{name}"), || {
            preprocess::preprocess(&bucketer, &ds.points, &cfg, 8)
                .stats
                .num_buckets()
        });
    }
    b.dump_json("embedding_bench");
}
