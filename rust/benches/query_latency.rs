//! End-to-end query latency (the Fig. 9 measurement, as a bench target).
//!
//! Runs the whole Neighborhood RPC pipeline — embed → retrieve → score →
//! sort — through the live coordinator, per (ScaNN-NN, Filter-P) cell, at
//! the default experiment scale divided by 4 to keep `cargo bench` fast.
//! The full-scale version is `experiments fig9`.

use dynamic_gus::bench::Bencher;
use dynamic_gus::config::{GusConfig, ScorerKind};
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::features::Point;

fn main() {
    let mut b = Bencher::new();
    for (name, ds) in [
        ("arxiv_like", SyntheticConfig::arxiv_like(5_000, 0x91).generate()),
        ("products_like", SyntheticConfig::products_like(7_500, 0x92).generate()),
    ] {
        for &filter_p in &[0.0f64, 10.0] {
            for &nn in &[10usize, 100, 1000] {
                let cfg = GusConfig {
                    scann_nn: nn,
                    filter_p,
                    scorer: ScorerKind::Auto,
                    ..GusConfig::default()
                };
                let gus =
                    DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 8).unwrap();
                let mut qi = 0usize;
                b.bench(
                    &format!("query/{name}/nn={nn}/filter_p={filter_p}"),
                    || {
                        qi = (qi + 7919) % ds.points.len();
                        gus.query(&ds.points[qi], nn).unwrap()
                    },
                );
            }
        }

        // Concurrent serving path: per-query latency of the batch RPC
        // across shard/thread configurations. (shards=1, threads=1) is the
        // sequential baseline the parallel cells are compared against.
        let batch_len = 64usize;
        for &(shards, threads) in &[(1usize, 1usize), (4, 1), (4, 4)] {
            let cfg = GusConfig {
                scann_nn: 100,
                n_shards: shards,
                query_threads: threads,
                scorer: ScorerKind::Auto,
                ..GusConfig::default()
            };
            let gus = DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 8).unwrap();
            let batch: Vec<Point> = ds.points.iter().take(batch_len).cloned().collect();
            b.bench_batch(
                &format!("query_batch{batch_len}/{name}/nn=100/shards={shards}/threads={threads}"),
                batch_len,
                || gus.query_batch(&batch, 100).unwrap(),
            );
        }
    }
    b.dump_json("query_latency");
}
