//! Scan-kernel microbench: `SparseAnn::scan_postings` in isolation.
//!
//! Every `top_k`/`threshold`/`query_batch` RPC bottoms out in this loop,
//! so its postings/sec IS the serving ceiling. The grid isolates the two
//! effects the SoA refactor targets:
//!
//! - **tombstone density** (1% / 25% / 75% dead postings): validation cost
//!   is one 4-byte compare against the dense generation array, so skipping
//!   tombstones should stay cheap as density grows (pre-SoA it was a
//!   ~64-byte `Slot` dereference — a likely cache miss — per posting);
//! - **budget + dim order**: budgeted rows compare selectivity order
//!   against the seed's query order on identical scan volume.
//!
//! Results land in `results/bench/hot_path.json` and are merged into the
//! repo-root `BENCH_index.json` perf-trajectory file together with
//! derived postings/sec figures. Regenerate with:
//!
//! ```text
//! cd rust && cargo bench --bench hot_path
//! ```

use std::collections::BTreeMap;

use dynamic_gus::bench::Bencher;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::embed::EmbeddingGenerator;
use dynamic_gus::index::{DimOrder, QueryParams, QueryScratch, SparseAnn};
use dynamic_gus::lsh::Bucketer;
use dynamic_gus::sparse::SparseVec;
use dynamic_gus::util::json::Json;

/// Build an index with ~`dead_fraction` of its postings tombstoned. The
/// compaction threshold is raised to 0.99 so the density holds instead of
/// being compacted away; returns the index plus surviving-point query
/// embeddings.
fn build(n: usize, dead_fraction: f64, seed: u64) -> (SparseAnn, Vec<SparseVec>) {
    let ds = SyntheticConfig::arxiv_like(n, seed).generate();
    let generator = EmbeddingGenerator::plain(Bucketer::with_defaults(&ds.schema, 0xb0a7));
    let mut index = SparseAnn::with_compact_threshold(0.99);
    let mut queries = Vec::new();
    let cut = (dead_fraction * 10_000.0) as u64;
    for (i, p) in ds.points.iter().enumerate() {
        let e = generator.embed(p);
        index.upsert(p.id, e.clone());
        // Deterministic pseudo-random victim selection at the target rate.
        if (i as u64).wrapping_mul(7919) % 10_000 < cut {
            index.remove(p.id);
        } else if queries.len() < 256 {
            queries.push(e);
        }
    }
    (index, queries)
}

fn main() {
    let mut b = Bencher::new();
    let mut throughput: BTreeMap<String, Json> = BTreeMap::new();
    let n = 20_000usize;
    for &(dname, frac) in &[("1pct", 0.01), ("25pct", 0.25), ("75pct", 0.75)] {
        let (index, queries) = build(n, frac, 0xb2);
        let st = index.stats();
        let total_entries = st.live_postings + st.dead_postings;
        let density = st.dead_postings as f64 / total_entries.max(1) as f64;
        let budget = (st.live_postings / 20).max(1);
        let mut scratch = QueryScratch::default();
        let configs = [
            ("exact", 0usize, DimOrder::Selectivity),
            ("budget5pct/selectivity", budget, DimOrder::Selectivity),
            ("budget5pct/query-order", budget, DimOrder::QueryOrder),
        ];
        for &(label, max_postings, order) in &configs {
            let params = QueryParams { exclude: None, max_postings };
            // Mean valid postings scored per query over the same rotation
            // the timed loop uses (the scan is deterministic).
            let total: usize = queries
                .iter()
                .map(|q| index.scan_postings(q, params, order, &mut scratch))
                .sum();
            let per_query = total as f64 / queries.len().max(1) as f64;
            let name = format!("hot_path/scan/dead={dname}/{label}");
            let mut qi = 0usize;
            b.bench(&name, || {
                qi = (qi + 1) % queries.len();
                index.scan_postings(&queries[qi], params, order, &mut scratch)
            });
            // `bench` skips names not matching a CLI filter: only attach
            // derived figures when this config actually ran.
            if let Some(r) = b.results().last().filter(|r| r.name == name) {
                let pps = if r.mean_ns > 0.0 { per_query * 1e9 / r.mean_ns } else { 0.0 };
                println!(
                    "    -> {per_query:.0} valid postings/query @ dead={:.1}%  ({:.1} M postings/s)",
                    density * 100.0,
                    pps / 1e6
                );
                let mut entry = BTreeMap::new();
                entry.insert("dead_density".to_string(), Json::num(density));
                entry.insert("postings_per_query".to_string(), Json::num(per_query));
                entry.insert("postings_per_sec".to_string(), Json::num(pps));
                entry.insert("mean_ns_per_scan".to_string(), Json::num(r.mean_ns));
                throughput.insert(name, Json::Obj(entry));
            }
        }
    }
    b.dump_json("hot_path");
    b.dump_repo_summary(
        "hot_path",
        vec![
            ("corpus_points".to_string(), Json::num(n as f64)),
            ("throughput".to_string(), Json::Obj(throughput)),
        ],
    );
}
