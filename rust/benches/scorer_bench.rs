//! Similarity-scorer benchmarks: native vs XLA/PJRT path, across candidate
//! batch sizes (the ScaNN-NN axis). The XLA rows exist only after
//! `make artifacts`.

use dynamic_gus::bench::Bencher;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::features::Point;
use dynamic_gus::runtime::artifacts_dir;
use dynamic_gus::scorer::{
    MlpWeights, NativeScorer, PairFeaturizer, PairScorer, XlaScorer,
};

fn main() {
    let mut b = Bencher::new();
    for (name, ds) in [
        ("arxiv_like", SyntheticConfig::arxiv_like(3_000, 0x5c).generate()),
        ("products_like", SyntheticConfig::products_like(3_000, 0x5d).generate()),
    ] {
        let featurizer = PairFeaturizer::new(&ds.schema);
        let weights_path = XlaScorer::weights_path(&artifacts_dir(), &ds.schema.name);
        let weights = if weights_path.exists() {
            MlpWeights::load(&weights_path).unwrap()
        } else {
            MlpWeights::random(featurizer.input_dim(), dynamic_gus::scorer::HIDDEN, 1)
        };
        let native = NativeScorer::new(featurizer.clone(), weights.clone());
        let q = &ds.points[0];
        for &nn in &[10usize, 100, 1000] {
            let cands: Vec<&Point> = ds.points[1..=nn].iter().collect();
            b.bench(&format!("scorer/native/{name}/batch={nn}"), || {
                native.score_batch(q, &cands)
            });
        }
        if XlaScorer::artifacts_available(&artifacts_dir(), &ds.schema.name) {
            let xla = XlaScorer::with_weights(featurizer, &artifacts_dir(), weights).unwrap();
            for &nn in &[10usize, 100, 1000] {
                let cands: Vec<&Point> = ds.points[1..=nn].iter().collect();
                b.bench(&format!("scorer/xla/{name}/batch={nn}"), || {
                    xla.score_batch(q, &cands)
                });
            }
        } else {
            eprintln!("[scorer_bench] no artifacts for {name}: skipping XLA rows");
        }
    }
    b.dump_json("scorer_bench");
}
