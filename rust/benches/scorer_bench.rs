//! Similarity-scorer benchmarks.
//!
//! Two families:
//!
//! - `scorer/native|xla/...` — the end-to-end scorer paths across candidate
//!   batch sizes (the ScaNN-NN axis). The XLA rows exist only after
//!   `make artifacts`.
//! - `scorer/pairs/...` — the kernel comparison the packed-tile work is
//!   judged by: scalar oracle vs packed tile kernel vs packed + scoped
//!   worker threads, at dense dim d ∈ {8, 64, 256}, 1024 pairs per call.
//!   `bench_batch` reports **per-pair** stats, and the derived pairs/sec
//!   figures are merged into the repo-root `BENCH_index.json` trajectory.

use dynamic_gus::bench::Bencher;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::features::{FeatureValue, Point, Schema};
use dynamic_gus::runtime::artifacts_dir;
use dynamic_gus::scorer::{
    score_into_parallel, MlpWeights, NativeScorer, PairFeaturizer, PairScorer, ScorerScratch,
    ScratchPool, XlaScorer, HIDDEN,
};
use dynamic_gus::util::json::Json;
use dynamic_gus::util::rng::Rng;
use dynamic_gus::util::threadpool::default_parallelism;

/// Pairs per kernel-cell iteration (large enough that the parallel split
/// engages: > SCORE_PAR_MIN).
const N_PAIRS: usize = 1024;

fn main() {
    let mut b = Bencher::new();
    for (name, ds) in [
        ("arxiv_like", SyntheticConfig::arxiv_like(3_000, 0x5c).generate()),
        ("products_like", SyntheticConfig::products_like(3_000, 0x5d).generate()),
    ] {
        let featurizer = PairFeaturizer::new(&ds.schema);
        let weights_path = XlaScorer::weights_path(&artifacts_dir(), &ds.schema.name);
        let weights = if weights_path.exists() {
            MlpWeights::load(&weights_path).unwrap()
        } else {
            MlpWeights::random(featurizer.input_dim(), HIDDEN, 1)
        };
        let native = NativeScorer::new(featurizer.clone(), weights.clone());
        let q = &ds.points[0];
        for &nn in &[10usize, 100, 1000] {
            let cands: Vec<&Point> = ds.points[1..=nn].iter().collect();
            let mut scratch = ScorerScratch::default();
            let mut out = Vec::with_capacity(nn);
            b.bench(&format!("scorer/native/{name}/batch={nn}"), || {
                out.clear();
                native.score_into(q, &cands, &mut scratch, &mut out);
                out.len()
            });
        }
        if XlaScorer::artifacts_available(&artifacts_dir(), &ds.schema.name) {
            let xla = XlaScorer::with_weights(featurizer, &artifacts_dir(), weights).unwrap();
            for &nn in &[10usize, 100, 1000] {
                let cands: Vec<&Point> = ds.points[1..=nn].iter().collect();
                b.bench(&format!("scorer/xla/{name}/batch={nn}"), || {
                    xla.score_batch(q, &cands)
                });
            }
        } else {
            eprintln!("[scorer_bench] no artifacts for {name}: skipping XLA rows");
        }
    }

    // --- kernel cells: scalar vs packed vs packed+threads, per dense dim ---
    let threads = default_parallelism();
    for &d in &[8usize, 64, 256] {
        let schema = Schema::arxiv_like(d);
        let f = PairFeaturizer::new(&schema);
        let w = MlpWeights::random(f.input_dim(), HIDDEN, 0xd0 + d as u64);
        let scorer = NativeScorer::new(f, w);
        let mut rng = Rng::seeded(0x9a17 + d as u64);
        let pts: Vec<Point> = (0..=N_PAIRS as u64)
            .map(|i| {
                Point::new(
                    i,
                    vec![
                        FeatureValue::Dense(rng.normal_vec_f32(d)),
                        FeatureValue::Scalar(2000.0 + rng.below(25) as f32),
                    ],
                )
            })
            .collect();
        let q = &pts[0];
        let cands: Vec<&Point> = pts[1..].iter().collect();

        b.bench_batch(&format!("scorer/pairs/scalar/d={d}"), cands.len(), || {
            scorer.score_batch_scalar(q, &cands)
        });

        let mut scratch = ScorerScratch::default();
        let mut out = Vec::with_capacity(cands.len());
        b.bench_batch(&format!("scorer/pairs/packed/d={d}"), cands.len(), || {
            out.clear();
            scorer.score_into(q, &cands, &mut scratch, &mut out);
            out.len()
        });

        let pool = ScratchPool::new();
        let mut pout = Vec::with_capacity(cands.len());
        b.bench_batch(
            &format!("scorer/pairs/packed+threads={threads}/d={d}"),
            cands.len(),
            || {
                pout.clear();
                score_into_parallel(&scorer, q, &cands, &pool, threads, &mut pout);
                pout.len()
            },
        );
    }

    b.dump_json("scorer_bench");
    // Derived pairs/sec for the perf trajectory (bench_batch stats are
    // per pair, so the rate is just the inverse of the mean).
    let extra: Vec<(String, Json)> = b
        .results()
        .iter()
        .filter(|r| r.name.starts_with("scorer/pairs/"))
        .map(|r| {
            let key = format!("pairs_per_sec/{}", &r.name["scorer/pairs/".len()..]);
            (key, Json::num(1e9 / r.mean_ns))
        })
        .collect();
    b.dump_repo_summary("scorer_bench", extra);
}
