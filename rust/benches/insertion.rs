//! Mutation latency (the §5.2 insertion measurement, as a bench target).
//!
//! Paper: median insertion 0.29 ms (ogbn-arxiv) / 0.42 ms (ogbn-products),
//! 95%ile 0.54 / 0.78 ms. The bench cycles insert→delete over a live
//! coordinator so the corpus size stays constant.

use dynamic_gus::bench::Bencher;
use dynamic_gus::config::{GusConfig, ScorerKind};
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::data::synthetic::SyntheticConfig;

fn main() {
    let mut b = Bencher::new();
    for (name, ds) in [
        ("arxiv_like", SyntheticConfig::arxiv_like(10_000, 0x1a).generate()),
        ("products_like", SyntheticConfig::products_like(10_000, 0x1b).generate()),
    ] {
        let split = ds.points.len() - 1_000;
        let cfg = GusConfig {
            filter_p: 10.0,
            scorer: ScorerKind::Native,
            ..GusConfig::default()
        };
        let gus =
            DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points[..split], 8).unwrap();
        let holdout = &ds.points[split..];
        let mut i = 0usize;
        b.bench(&format!("mutation/insert/{name}"), || {
            let p = holdout[i % holdout.len()].clone();
            i += 1;
            let existed = gus.insert(p).unwrap();
            existed
        });
        b.bench(&format!("mutation/update/{name}"), || {
            let p = ds.points[i % split].clone();
            i += 1;
            gus.insert(p).unwrap()
        });
        b.bench(&format!("mutation/delete_reinsert/{name}"), || {
            let p = ds.points[i % split].clone();
            i += 1;
            gus.delete(p.id).unwrap();
            gus.insert(p).unwrap()
        });
    }
    b.dump_json("insertion");
}
