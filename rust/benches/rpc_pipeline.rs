//! RPC transport throughput/latency: serial vs pipelined vs multi-conn.
//!
//! This is the measurement behind the protocol-v1 redesign: a real
//! server and real sockets, comparing three ways to push the same
//! `query_id` workload through the RPC layer:
//!
//! - `serial/1conn` — the pre-envelope model: one connection, one
//!   request in flight (submit → wait → submit …);
//! - `pipelined/1conn/depth=D` — one connection, D requests in flight
//!   (the envelope's multiplexing win), D ∈ {1, 8, 64};
//! - `parallel/{N}conn` — N connections, each serial (the old way to
//!   get concurrency: more sockets).
//!
//! All rows report **per-request** stats (pipelined rows divide by the
//! depth), so the multiplexing win over the serial baseline is measured,
//! not asserted. `depth=1` should track `serial/1conn`; `depth=64` on a
//! multi-core box should approach `parallel/Nconn` with one socket.

use std::sync::Arc;

use dynamic_gus::bench::{fmt_ns, Bencher};
use dynamic_gus::client::GusClient;
use dynamic_gus::config::{GusConfig, ScorerKind};
use dynamic_gus::coordinator::DynamicGus;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::protocol::Request;
use dynamic_gus::server::{serve, ServerConfig};

fn main() {
    let n = 5_000usize;
    let k = 10usize;
    let ds = SyntheticConfig::arxiv_like(n, 0x9c9).generate();
    let cfg = GusConfig { scorer: ScorerKind::Native, ..GusConfig::default() };
    let gus =
        Arc::new(DynamicGus::bootstrap(ds.schema.clone(), cfg, &ds.points, 4).unwrap());
    let handle = serve(Arc::clone(&gus), "127.0.0.1:0", ServerConfig::default()).unwrap();
    let addr = handle.addr.to_string();
    let ids: Vec<u64> = ds.points.iter().map(|p| p.id).collect();

    let mut b = Bencher::new();

    // Serial baseline: one request in flight at a time.
    {
        let mut client = GusClient::connect(&addr).unwrap();
        let mut i = 0usize;
        b.bench("rpc/serial/1conn", || {
            i = (i + 7919) % ids.len();
            client.query_id(ids[i], k).unwrap()
        });
    }

    // Pipelined: one connection, `depth` requests in flight per batch.
    for &depth in &[1usize, 8, 64] {
        let mut client = GusClient::connect(&addr).unwrap();
        let mut i = 0usize;
        b.bench_batch(&format!("rpc/pipelined/1conn/depth={depth}"), depth, || {
            let reqs: Vec<u64> = (0..depth)
                .map(|_| {
                    i = (i + 7919) % ids.len();
                    client.submit(Request::QueryId { id: ids[i], k: Some(k) }).unwrap()
                })
                .collect();
            let mut total = 0usize;
            for rid in reqs {
                total += client.wait_neighbors(rid).unwrap().len();
            }
            total
        });
    }

    // N serial connections in parallel (custom measurement: the Bencher
    // times one closure, but this row needs concurrent wall-clock).
    for &conns in &[4usize, 8] {
        let per_conn = 400usize;
        let t0 = std::time::Instant::now();
        let mut samples: Vec<f64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..conns)
                .map(|t| {
                    let addr = addr.clone();
                    let ids = &ids;
                    s.spawn(move || {
                        let mut client = GusClient::connect(&addr).unwrap();
                        let mut local = Vec::with_capacity(per_conn);
                        for j in 0..per_conn {
                            let id = ids[(t * 37 + j * 7919) % ids.len()];
                            let q0 = std::time::Instant::now();
                            client.query_id(id, k).unwrap();
                            local.push(q0.elapsed().as_nanos() as f64);
                        }
                        local
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let wall = t0.elapsed();
        samples.sort_by(|a, b| a.total_cmp(b));
        let pct = |p: f64| samples[((p * (samples.len() - 1) as f64) as usize).min(samples.len() - 1)];
        let total = conns * per_conn;
        println!(
            "{:<58} {:>10}/req   (p50 {:>10}, p99 {:>10}, {:.0} req/s over {} conns)",
            format!("rpc/parallel/{conns}conn"),
            fmt_ns(samples.iter().sum::<f64>() / samples.len() as f64),
            fmt_ns(pct(0.50)),
            fmt_ns(pct(0.99)),
            total as f64 / wall.as_secs_f64(),
            conns
        );
    }

    b.dump_json("rpc_pipeline");
    handle.shutdown();
}
