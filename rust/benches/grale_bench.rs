//! Grale-baseline cost benchmarks: the offline build the paper's dynamic
//! system replaces. One row per (Bucket-S, Top-K) cell at bench scale —
//! demonstrates that Grale's cost does NOT drop with Top-K (the paper's
//! §5.1 third-experiment observation), while GUS's does with ScaNN-NN.

use dynamic_gus::bench::Bencher;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::eval::offline::{grale_run, gus_offline, GusOfflineParams};

fn main() {
    let mut b = Bencher::new();
    // Small corpus: each iteration is a FULL graph build.
    let ds = SyntheticConfig::arxiv_like(2_000, 0x6b).generate();
    for &bucket_s in &[10usize, 100, 1000] {
        b.bench(&format!("grale/full_build/bucket_s={bucket_s}"), || {
            grale_run(&ds, Some(bucket_s), None, 8).scored_pairs
        });
    }
    // Top-K does not reduce Grale's cost...
    for &k in &[10usize, 100] {
        b.bench(&format!("grale/full_build/bucket_s=100/top_k={k}"), || {
            grale_run(&ds, Some(100), Some(k), 8).scored_pairs
        });
    }
    // ...but ScaNN-NN does reduce GUS's.
    for &nn in &[10usize, 100] {
        b.bench(&format!("gus/offline_build/nn={nn}"), || {
            gus_offline(&ds, GusOfflineParams { nn, idf_s: 0, filter_p: 10.0 }, 8)
                .directed_edges
        });
    }
    b.dump_json("grale_bench");
}
