//! Concurrent serving-path throughput: sharded fan-out and the batch APIs.
//!
//! This is the measurement behind the PR that parallelized `ShardedIndex`:
//! every row reports **per-item** latency (`bench_batch` divides by the
//! batch size), so the three serving strategies compare directly per
//! (shards, threads) cell:
//!
//! - `top_k` — one query, shards scanned on the worker threads;
//! - `query_batch` — 64 queries per call, parallelized across queries;
//! - `upsert_batch` — 64 mutations per call, one write-lock take per shard.
//!
//! The `(shards=1, threads=1)` rows are the sequential seed baseline; the
//! multi-shard/multi-thread rows must beat them on ≥ 4 cores.

use dynamic_gus::bench::Bencher;
use dynamic_gus::data::synthetic::SyntheticConfig;
use dynamic_gus::embed::EmbeddingGenerator;
use dynamic_gus::index::sharded::ShardedIndex;
use dynamic_gus::index::QueryParams;
use dynamic_gus::lsh::Bucketer;
use dynamic_gus::sparse::SparseVec;

fn build(n: usize, shards: usize, threads: usize) -> (ShardedIndex, Vec<SparseVec>) {
    let ds = SyntheticConfig::arxiv_like(n, 0xba7c).generate();
    let generator = EmbeddingGenerator::plain(Bucketer::with_defaults(&ds.schema, 0xe7a1));
    let ix = ShardedIndex::with_threads(shards, threads);
    let mut embeddings = Vec::with_capacity(n);
    for p in &ds.points {
        let e = generator.embed(p);
        ix.upsert(p.id, e.clone());
        embeddings.push(e);
    }
    (ix, embeddings)
}

fn main() {
    let mut b = Bencher::new();
    let n = 20_000usize;
    let k = 100usize;
    let batch = 64usize;
    for &(shards, threads) in &[(1usize, 1usize), (4, 1), (4, 4), (8, 8)] {
        let (ix, embeddings) = build(n, shards, threads);

        let mut qi = 0usize;
        b.bench(
            &format!("sharded/top_k/k={k}/shards={shards}/threads={threads}"),
            || {
                qi = (qi + 7919) % embeddings.len();
                ix.top_k(&embeddings[qi], k, QueryParams::default())
            },
        );

        let queries: Vec<(SparseVec, QueryParams)> = (0..batch)
            .map(|i| {
                (
                    embeddings[(i * 7919) % embeddings.len()].clone(),
                    QueryParams::default(),
                )
            })
            .collect();
        b.bench_batch(
            &format!("sharded/query_batch{batch}/k={k}/shards={shards}/threads={threads}"),
            batch,
            || ix.query_batch(&queries, k),
        );

        // Mutation path: re-upsert a sliding window of existing points so
        // the corpus size stays constant across iterations.
        let mut base = 0u64;
        b.bench_batch(
            &format!("sharded/upsert_batch{batch}/shards={shards}/threads={threads}"),
            batch,
            || {
                base = (base + 131) % n as u64;
                let items: Vec<(u64, SparseVec)> = (0..batch as u64)
                    .map(|i| {
                        let id = (base + i) % n as u64;
                        (id, embeddings[id as usize].clone())
                    })
                    .collect();
                ix.upsert_batch(items)
            },
        );
    }
    b.dump_json("batch_throughput");
}
