//! Offline vendored subset of the `anyhow` error-handling crate.
//!
//! The build environment has no crates.io access, so this path crate
//! implements exactly the surface `dynamic_gus` uses, with the same
//! semantics as the real crate for that subset:
//!
//! - [`Error`]: an opaque, message-carrying error type (`Send + Sync`);
//! - [`Result<T>`]: `std::result::Result<T, Error>` with a defaulted error
//!   parameter;
//! - `?` conversion from any `std::error::Error + Send + Sync + 'static`;
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, prepending context to the message chain;
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros (literal, single-expression
//!   and format-args forms).
//!
//! Unlike the real crate there is no backtrace capture and no downcasting —
//! nothing in this repository uses either. Swap this path dependency for
//! the real `anyhow` when building online.

use std::fmt;

/// `Result` with a defaulted [`Error`], mirroring `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error carrying a human-readable message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend a context line, like `anyhow::Error::context`.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `?` conversion from standard error types. `Error` itself deliberately
// does NOT implement `std::error::Error`, so this blanket impl cannot
// overlap the identity `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Attach context to errors, mirroring `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a formattable value, or format
/// args — the three forms of `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error, like `anyhow::bail!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition fails, like `anyhow::ensure!`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let x = 7;
        let e = anyhow!("x = {x}");
        assert_eq!(format!("{e}"), "x = 7");
        let e = anyhow!("x = {}", 9);
        assert_eq!(format!("{e}"), "x = 9");
        let e = anyhow!(String::from("owned"));
        assert_eq!(format!("{e:?}"), "owned");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted ok");
            if !ok {
                bail!("unreachable {}", 1);
            }
            Ok(5)
        }
        assert_eq!(f(true).unwrap(), 5);
        assert_eq!(format!("{}", f(false).unwrap_err()), "wanted ok");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(format!("{}", f().unwrap_err()).contains("gone"));
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "f.txt")).unwrap_err();
        assert_eq!(format!("{e}"), "reading f.txt: gone");
        let o: Option<u32> = None;
        let e = o.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
    }
}
