//! Type-level stub of the `xla` crate (XLA/PJRT bindings).
//!
//! The offline build environment carries neither the `xla` crate nor the
//! `xla_extension` shared library, so this path crate provides the exact
//! API surface `dynamic_gus::runtime` and `dynamic_gus::scorer::xla`
//! compile against. Every entry point that would need the real runtime
//! returns [`XlaError`]; in particular [`PjRtClient::cpu`] fails, which is
//! the single choke point the serving stack already handles:
//!
//! - `ScorerKind::Auto` falls back to the native scorer;
//! - `XlaScorer` construction reports a load error instead of serving;
//! - XLA-dependent tests detect the unavailable engine and skip with a
//!   visible message (same convention as the missing-artifacts skips).
//!
//! Swap the `vendor/xla` path dependency in `rust/Cargo.toml` for the real
//! crate to enable the PJRT path; no source changes are needed.

use std::fmt;
use std::path::Path;

/// Error type standing in for the real crate's `xla::Error`.
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: XLA/PJRT runtime not available in this build \
         (rust/vendor/xla is a stub; swap it for the real crate)"
    )))
}

/// PJRT client handle. The stub can never be constructed: [`PjRtClient::cpu`]
/// always errors, so the methods below are unreachable at runtime.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        unavailable(&format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        ))
    }
}

/// An XLA computation (stub).
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A loaded executable (stub; unreachable without a client).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// A device buffer (stub; unreachable without a client).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }

    pub fn on_device_shape(&self) -> Result<Shape> {
        unavailable("PjRtBuffer::on_device_shape")
    }
}

/// A host literal (stub).
pub struct Literal(());

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn shape(&self) -> Result<Shape> {
        unavailable("Literal::shape")
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Array shape metadata.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Shape of a literal or buffer.
#[derive(Debug, Clone)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

impl Shape {
    /// Array shape with the given dimensions; the element type parameter
    /// mirrors the real crate's signature.
    pub fn array<T: 'static>(dims: Vec<i64>) -> Shape {
        Shape::Array(ArrayShape { dims })
    }
}

/// Computation builder (stub; operations error).
pub struct XlaBuilder(());

impl XlaBuilder {
    pub fn new(_name: &str) -> XlaBuilder {
        XlaBuilder(())
    }

    pub fn parameter_s(&self, _number: i64, _shape: &Shape, _name: &str) -> Result<XlaOp> {
        unavailable("XlaBuilder::parameter_s")
    }
}

/// A node in a computation under construction (stub).
pub struct XlaOp(());

impl XlaOp {
    pub fn build(&self) -> Result<XlaComputation> {
        unavailable("XlaOp::build")
    }
}

impl std::ops::Add for XlaOp {
    type Output = Result<XlaOp>;

    fn add(self, _rhs: XlaOp) -> Result<XlaOp> {
        unavailable("XlaOp::add")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must error");
        assert!(format!("{err}").contains("not available"), "{err}");
    }

    #[test]
    fn shape_helpers_work() {
        match Shape::array::<f32>(vec![2, 3]) {
            Shape::Array(a) => assert_eq!(a.dims(), &[2, 3]),
            other => panic!("unexpected {other:?}"),
        }
    }
}
