//! Fixture-driven self-tests for gus-lint, plus a self-run asserting the
//! real tree is lint-clean at HEAD.
//!
//! Fixtures live under `tests/fixtures/<rule>/{good,bad}.rs`; they are
//! lexed by the linter but never compiled (and the `fixtures` directory
//! is on the linter's own skip list, so tree-wide runs ignore them).

use std::path::{Path, PathBuf};

fn fixture(rel: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(rel);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

/// Lint a fixture, using its relative path as the diagnostic path.
fn lint_fixture(rel: &str) -> Vec<gus_lint::Finding> {
    gus_lint::lint_source(rel, &fixture(rel))
}

fn assert_all_rule(findings: &[gus_lint::Finding], rule: &str) {
    assert!(
        findings.iter().all(|f| f.rule == rule),
        "expected only [{rule}] findings, got {findings:?}"
    );
}

#[test]
fn float_sort_safety_fixtures() {
    let bad = lint_fixture("float-sort-safety/bad.rs");
    assert!(bad.len() >= 5, "missed NaN-unsafe sorts: {bad:?}");
    assert_all_rule(&bad, "float-sort-safety");
    let good = lint_fixture("float-sort-safety/good.rs");
    assert!(good.is_empty(), "false positives: {good:?}");
}

#[test]
fn undocumented_unsafe_fixtures() {
    let bad = lint_fixture("undocumented-unsafe/bad.rs");
    assert_eq!(bad.len(), 2, "expected both undocumented sites: {bad:?}");
    assert_all_rule(&bad, "undocumented-unsafe");
    let good = lint_fixture("undocumented-unsafe/good.rs");
    assert!(good.is_empty(), "false positives: {good:?}");
}

#[test]
fn relaxed_ordering_fixtures() {
    let bad = lint_fixture("relaxed-ordering-audit/bad.rs");
    assert_eq!(bad.len(), 2, "expected both unjustified sites: {bad:?}");
    assert_all_rule(&bad, "relaxed-ordering-audit");
    let good = lint_fixture("relaxed-ordering-audit/good.rs");
    assert!(good.is_empty(), "false positives: {good:?}");
}

#[test]
fn multi_lock_fixtures() {
    let bad = lint_fixture("multi-lock-inventory/bad.rs");
    assert!(bad.len() >= 2, "missed multi-lock holds: {bad:?}");
    assert_all_rule(&bad, "multi-lock-inventory");
    assert!(
        bad.iter().any(|f| f.msg.contains("closure returns a lock guard")),
        "missed the guard-escaping-closure case: {bad:?}"
    );
    // good.rs includes an allowlisted `get_many` holding two guards.
    let good = lint_fixture("multi-lock-inventory/good.rs");
    assert!(good.is_empty(), "false positives: {good:?}");
}

#[test]
fn replay_determinism_is_path_scoped() {
    let src = fixture("replay-determinism/bad.rs");
    let in_wal = gus_lint::lint_source("coordinator/wal.rs", &src);
    assert!(in_wal.len() >= 3, "missed nondeterminism: {in_wal:?}");
    assert_all_rule(&in_wal, "replay-determinism");
    // The fault-injection layer carries the chaos drill's seed-replay
    // contract, so it is in scope — except the proxy, which executes
    // schedules against real sockets and legitimately reads the clock.
    for covered in ["fault/plan.rs", "fault/injector.rs", "fault/backoff.rs", "fault/schedule.rs"] {
        let in_fault = gus_lint::lint_source(covered, &src);
        assert!(in_fault.len() >= 3, "{covered} not covered: {in_fault:?}");
        assert_all_rule(&in_fault, "replay-determinism");
    }
    // The same source outside the replay-critical set is not flagged.
    for exempt in ["src/server.rs", "fault/proxy.rs"] {
        let elsewhere = gus_lint::lint_source(exempt, &src);
        assert!(elsewhere.is_empty(), "rule leaked into {exempt}: {elsewhere:?}");
    }
    let good = fixture("replay-determinism/good.rs");
    let good_fs = gus_lint::lint_source("coordinator/wal.rs", &good);
    assert!(good_fs.is_empty(), "false positives: {good_fs:?}");
}

#[test]
fn repr_c_fixtures() {
    let bad = lint_fixture("repr-c-size-assert/bad.rs");
    assert_eq!(bad.len(), 1, "expected the missing-assert finding: {bad:?}");
    assert_all_rule(&bad, "repr-c-size-assert");
    let good = lint_fixture("repr-c-size-assert/good.rs");
    assert!(good.is_empty(), "false positives: {good:?}");
}

#[test]
fn suppression_fixture_is_clean() {
    let fs = lint_fixture("suppression/suppress.rs");
    assert!(fs.is_empty(), "lint:allow must silence these: {fs:?}");
}

/// The acceptance gate: the repo's own Rust tree must be clean. Runs the
/// library directly (same code path as the `gus-lint` binary) over
/// `rust/{src,tests,benches,tools}`.
#[test]
fn tree_is_clean_at_head() {
    let rust_root: PathBuf = Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("tools/lint sits two levels under rust/")
        .to_path_buf();
    let paths: Vec<PathBuf> =
        ["src", "tests", "benches", "tools"].iter().map(|d| rust_root.join(d)).collect();
    let (findings, n_files) = gus_lint::lint_paths(&paths);
    assert!(n_files > 50, "expected to lint the whole tree, saw only {n_files} files");
    let report: Vec<String> = findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.msg))
        .collect();
    assert!(findings.is_empty(), "gus-lint must be clean at HEAD:\n{}", report.join("\n"));
}
