// Fixture: NaN-unsafe float comparisons. Every partial_cmp below must be
// flagged (these files are lexed, never compiled).
fn sorts(v: &mut Vec<f32>) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap().then(std::cmp::Ordering::Equal));
    let _m = v.iter().max_by(|a, b| a.partial_cmp(b).unwrap());
    let _c = 1.0f32.partial_cmp(&2.0).unwrap();
    let _e = 1.0f32.partial_cmp(&2.0).expect("cmp");
}
