// Fixture: NaN-total comparisons — the rule must stay quiet.
fn sorts(v: &mut Vec<f32>) {
    v.sort_by(|a, b| a.total_cmp(b));
    v.sort_unstable_by(|a, b| b.total_cmp(a).then(std::cmp::Ordering::Equal));
    let _m = v.iter().max_by(|a, b| a.total_cmp(b));
    // partial_cmp without unwrap/expect is fine outside sort closures:
    let _o = 1.0f32.partial_cmp(&2.0);
}
// Defining a fn named partial_cmp is not a call site.
fn partial_cmp() {}
