// Fixture: documented unsafe — the rule must stay quiet.
fn deref(p: *const u8) -> u8 {
    // SAFETY: caller guarantees `p` is valid for reads.
    unsafe { *p }
}
struct W(usize);
// SAFETY: W is a plain integer; sharing it across threads cannot race.
unsafe impl Sync for W {}
fn same_line(p: *const u8) -> u8 {
    unsafe { *p } // SAFETY: same-line comments attach too.
}
