// Fixture: unsafe without SAFETY comments — both sites must be flagged.
fn deref(p: *const u8) -> u8 {
    unsafe { *p }
}
struct W(usize);
unsafe impl Sync for W {}
