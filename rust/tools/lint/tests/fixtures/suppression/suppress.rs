// Fixture: `// lint:allow(rule)` silences a finding on the same line or
// via the comment block directly above. Everything here must be clean.

fn sorts(v: &mut Vec<f32>) {
    // lint:allow(float-sort-safety)
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

fn deref(p: *const u8) -> u8 {
    unsafe { *p } // lint:allow(undocumented-unsafe)
}

use std::sync::atomic::{AtomicU64, Ordering};
fn toggle(flag: &AtomicU64) {
    // A multi-rule allow list also works:
    // lint:allow(relaxed-ordering-audit, repr-c-size-assert)
    flag.store(1, Ordering::Relaxed);
}
