// Fixture: unjustified Ordering::Relaxed on a non-allowlisted ident —
// both atomic operations must be flagged.
use std::sync::atomic::{AtomicU64, Ordering};
fn toggle(flag: &AtomicU64) -> u64 {
    flag.store(1, Ordering::Relaxed);
    flag.load(Ordering::Relaxed)
}
