// Fixture: audited Relaxed uses — the rule must stay quiet.
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::atomic::Ordering::Relaxed;
fn bump(queries: &AtomicU64, flag: &AtomicU64) -> u64 {
    // `queries` is an allowlisted monotonic counter.
    queries.fetch_add(1, Ordering::Relaxed);
    // RELAXED: advisory flag; readers tolerate staleness.
    flag.store(1, Ordering::Relaxed);
    flag.load(Relaxed) // RELAXED: same justification as the store above.
}
