// Fixture: deterministic replay code — ordered maps, no wall clock.
use std::collections::BTreeMap;
fn replay(ticks: u64) -> u64 {
    let mut m: BTreeMap<u64, u64> = BTreeMap::new();
    m.insert(1, ticks);
    m.values().sum()
}
