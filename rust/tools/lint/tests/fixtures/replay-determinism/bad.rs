// Fixture: nondeterminism in a replay-critical file. The self-test lints
// this source under the path `coordinator/wal.rs`; every HashMap mention
// and the Instant::now call must be flagged there (and none of them under
// a non-replay path).
use std::collections::HashMap;
use std::time::Instant;
fn replay() -> u64 {
    let t0 = Instant::now();
    let mut m: HashMap<u64, u64> = HashMap::new();
    m.insert(1, 2);
    t0.elapsed().as_nanos() as u64
}
