// Fixture: asserted #[repr(C)] layout, plus a non-C repr that needs no
// assertion — the rule must stay quiet.
#[repr(C)]
#[derive(Clone, Copy)]
pub struct Posting {
    pub id: u64,
    pub weight: f32,
}
const _: () = assert!(std::mem::size_of::<Posting>() == 12);

#[repr(align(64))]
struct Padded(u8);
