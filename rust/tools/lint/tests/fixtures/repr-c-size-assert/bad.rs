// Fixture: #[repr(C)] type without a compile-time size assertion.
#[repr(C)]
pub struct Posting {
    pub id: u64,
    pub weight: f32,
}
