// Fixture: functions holding two live guards or leaking guards out of
// closures — both fns must be flagged.
use std::sync::Mutex;
fn two_guards(a: &Mutex<u32>, b: &Mutex<u32>) -> u32 {
    let ga = a.lock().unwrap();
    let gb = b.lock().unwrap();
    *ga + *gb
}
fn guard_escapes(items: &[Mutex<u32>]) -> u32 {
    items.iter().map(|m| m.lock().unwrap()).map(|g| *g).sum()
}
