// Fixture: lock use the rule must stay quiet on.
use std::sync::Mutex;
fn sequential(a: &Mutex<Vec<u32>>, b: &Mutex<Vec<u32>>) -> u32 {
    // Temporaries: the chain continues past unwrap, so no guard is live
    // when the second lock is taken.
    let x: u32 = a.lock().unwrap().iter().sum();
    let y: u32 = b.lock().unwrap().iter().sum();
    x + y
}
fn one_at_a_time(a: &Mutex<u32>) -> u32 {
    let g = a.lock().unwrap();
    *g + 1
}
fn get_many(a: &Mutex<u32>, b: &Mutex<u32>) -> u32 {
    // Allowlisted audited fn: holding two guards here is deliberate.
    let ga = a.lock().unwrap();
    let gb = b.lock().unwrap();
    *ga + *gb
}
