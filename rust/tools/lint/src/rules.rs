//! The lint rules. Each rule walks the token stream produced by
//! [`crate::lexer::lex`] and appends findings; suppression filtering
//! (`// lint:allow(rule)`) happens once in [`crate::lint_source`].
//!
//! Every rule is derived from a bug class this repo has actually shipped
//! or audited — see docs/LINTS.md for the history and the exact
//! semantics of each heuristic.

use crate::lexer::{Kind, LineInfo, Token};

/// One diagnostic: `path:line: [rule] msg`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub path: String,
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

// ---------- token helpers ----------

fn pch(t: &Token) -> Option<char> {
    if t.kind == Kind::Punct {
        t.text.chars().next()
    } else {
        None
    }
}

fn is_p(t: &Token, ch: char) -> bool {
    pch(t) == Some(ch)
}

fn is_id(t: &Token, s: &str) -> bool {
    t.kind == Kind::Ident && t.text == s
}

fn is_open(c: char) -> bool {
    matches!(c, '(' | '[' | '{')
}

fn is_close(c: char) -> bool {
    matches!(c, ')' | ']' | '}')
}

fn close_of(c: char) -> char {
    match c {
        '(' => ')',
        '[' => ']',
        _ => '}',
    }
}

fn open_of(c: char) -> char {
    match c {
        ')' => '(',
        ']' => '[',
        _ => '{',
    }
}

/// `toks[i]` is an open bracket; index of the matching close (or the last
/// token when unbalanced — rules treat that as "rest of file").
fn match_fwd(toks: &[Token], i: usize) -> usize {
    let want = pch(&toks[i]).unwrap_or('(');
    let mut depth = 0i64;
    for (j, t) in toks.iter().enumerate().skip(i) {
        if let Some(c) = pch(t) {
            if c == want {
                depth += 1;
            } else if c == close_of(want) {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
        }
    }
    toks.len() - 1
}

/// `toks[i]` is a close bracket; index of the matching open (or 0).
fn match_back(toks: &[Token], i: usize) -> usize {
    let want = pch(&toks[i]).unwrap_or(')');
    let mut depth = 0i64;
    let mut j = i as i64;
    while j >= 0 {
        if let Some(c) = pch(&toks[j as usize]) {
            if c == want {
                depth += 1;
            } else if c == open_of(want) {
                depth -= 1;
                if depth == 0 {
                    return j as usize;
                }
            }
        }
        j -= 1;
    }
    0
}

// ---------- comment attachment ----------

/// Does `needle` appear in a comment on `line` or in the contiguous block
/// of comment-only lines directly above it?
pub(crate) fn block_has(lines: &[LineInfo], line: usize, needle: &str) -> bool {
    if lines[line].comment.contains(needle) {
        return true;
    }
    let mut l = line;
    while l > 1 {
        l -= 1;
        let li = &lines[l];
        if li.has_code || li.comment.is_empty() {
            break;
        }
        if li.comment.contains(needle) {
            return true;
        }
    }
    false
}

fn allow_hits(text: &str, rule: &str) -> bool {
    let mut rest = text;
    while let Some(p) = rest.find("lint:allow(") {
        let after = &rest[p + "lint:allow(".len()..];
        let Some(close) = after.find(')') else {
            return false;
        };
        let names = &after[..close];
        if names.split(',').any(|s| {
            let s = s.trim();
            s == rule || s == "all"
        }) {
            return true;
        }
        rest = &after[close + 1..];
    }
    false
}

/// Is `rule` suppressed at `line` via `// lint:allow(rule)` on the same
/// line or the contiguous comment block above?
pub(crate) fn suppressed(lines: &[LineInfo], line: usize, rule: &str) -> bool {
    if allow_hits(&lines[line].comment, rule) {
        return true;
    }
    let mut l = line;
    while l > 1 {
        l -= 1;
        let li = &lines[l];
        if li.has_code || li.comment.is_empty() {
            break;
        }
        if allow_hits(&li.comment, rule) {
            return true;
        }
    }
    false
}

// ---------- rule: float-sort-safety ----------

const SORT_FAMILY: &[&str] =
    &["sort_by", "sort_unstable_by", "max_by", "min_by", "binary_search_by"];

/// `partial_cmp(..).unwrap()` (or `.expect`) and `partial_cmp` inside a
/// sort-family comparator both panic or misorder the moment a NaN reaches
/// them; `total_cmp` is the NaN-total replacement.
fn rule_float_sort(path: &str, toks: &[Token], out: &mut Vec<Finding>) {
    let mut flagged: std::collections::BTreeSet<(usize, String)> = Default::default();
    for (i, t) in toks.iter().enumerate() {
        if is_id(t, "partial_cmp") {
            if i > 0 && is_id(&toks[i - 1], "fn") {
                continue; // defining partial_cmp, not calling it
            }
            if i + 1 < toks.len() && is_p(&toks[i + 1], '(') {
                let j = match_fwd(toks, i + 1);
                if j + 2 < toks.len()
                    && is_p(&toks[j + 1], '.')
                    && matches!(toks[j + 2].text.as_str(), "unwrap" | "expect")
                    && toks[j + 2].kind == Kind::Ident
                {
                    flagged.insert((
                        t.line,
                        format!(
                            "partial_cmp(..).{}() panics on NaN; use total_cmp",
                            toks[j + 2].text
                        ),
                    ));
                }
            }
        }
        if t.kind == Kind::Ident
            && SORT_FAMILY.contains(&t.text.as_str())
            && i + 1 < toks.len()
            && is_p(&toks[i + 1], '(')
        {
            let j = match_fwd(toks, i + 1);
            for inner in toks.iter().take(j).skip(i + 2) {
                if is_id(inner, "partial_cmp") {
                    flagged.insert((
                        inner.line,
                        format!(
                            "partial_cmp comparator in {}(..) panics or misorders on NaN; \
                             use total_cmp",
                            t.text
                        ),
                    ));
                }
            }
        }
    }
    for (line, msg) in flagged {
        out.push(Finding { path: path.to_string(), line, rule: "float-sort-safety", msg });
    }
}

// ---------- rule: undocumented-unsafe ----------

/// Every `unsafe` keyword (block, fn, impl) must carry a `// SAFETY:`
/// comment on the same line or the comment block directly above.
fn rule_unsafe(path: &str, toks: &[Token], lines: &[LineInfo], out: &mut Vec<Finding>) {
    for t in toks {
        if is_id(t, "unsafe") && !block_has(lines, t.line, "SAFETY:") {
            out.push(Finding {
                path: path.to_string(),
                line: t.line,
                rule: "undocumented-unsafe",
                msg: "`unsafe` without a `// SAFETY:` comment documenting the invariant"
                    .to_string(),
            });
        }
    }
}

// ---------- rule: relaxed-ordering-audit ----------

/// Idents on which `Ordering::Relaxed` is pre-audited: monotonic counters
/// whose readers tolerate staleness, plus latency-histogram cells.
const RELAXED_COUNTERS: &[&str] = &[
    // monotonic service/ingest counters
    "inserts",
    "updates",
    "deletes",
    "queries",
    "errors",
    "refused",
    "overloaded",
    "deadline_exceeded",
    "candidates_retrieved",
    "pairs_scored",
    "pairs_scored_ns",
    "applied",
    "submitted",
    "pending",
    "postings_scanned",
    // latency-histogram cells (independent; snapshots are best-effort)
    "buckets",
    "count",
    "sum_ns",
    "max_ns",
    "min_ns",
    // test-only hit counters
    "hits",
];

/// Token ranges covered by `use ...;` items (a `use atomic::Ordering::
/// Relaxed;` is not an atomic operation).
fn use_ranges(toks: &[Token]) -> Vec<(usize, usize)> {
    let mut rs = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if is_id(&toks[i], "use") {
            let mut j = i;
            while j < toks.len() && !is_p(&toks[j], ';') {
                j += 1;
            }
            rs.push((i, j));
            i = j;
        }
        i += 1;
    }
    rs
}

/// `toks[i]` is `Relaxed` inside a call's argument list; walk back to the
/// receiver of the atomic method call: `recv.load(Ordering::Relaxed)` or
/// `arr[k].fetch_add(1, Relaxed)` yield `recv` / `arr`.
fn receiver_of(toks: &[Token], i: usize) -> Option<String> {
    let mut depth = 0i64;
    let mut j = i as i64 - 1;
    let mut open = None;
    while j > 0 {
        if let Some(c) = pch(&toks[j as usize]) {
            if is_close(c) {
                depth += 1;
            } else if is_open(c) {
                if depth == 0 {
                    open = Some(j as usize);
                    break;
                }
                depth -= 1;
            }
        }
        j -= 1;
    }
    let j = open?;
    if !is_p(&toks[j], '(') || j < 1 {
        return None;
    }
    let m = j - 1;
    if m < 1 || toks[m].kind != Kind::Ident {
        return None;
    }
    let d = m - 1;
    if d < 1 || !is_p(&toks[d], '.') {
        return None;
    }
    let r = d - 1;
    if toks[r].kind == Kind::Ident {
        return Some(toks[r].text.clone());
    }
    if pch(&toks[r]).is_some_and(is_close) {
        let o = match_back(toks, r);
        if o >= 1 && toks[o - 1].kind == Kind::Ident {
            return Some(toks[o - 1].text.clone());
        }
    }
    None
}

/// `Ordering::Relaxed` must target an allowlisted counter or carry a
/// `// RELAXED:` justification.
fn rule_relaxed(path: &str, toks: &[Token], lines: &[LineInfo], out: &mut Vec<Finding>) {
    let uses = use_ranges(toks);
    for (i, t) in toks.iter().enumerate() {
        if !is_id(t, "Relaxed") {
            continue;
        }
        if uses.iter().any(|&(a, b)| (a..=b).contains(&i)) {
            continue;
        }
        let recv = receiver_of(toks, i);
        if recv.as_deref().is_some_and(|r| RELAXED_COUNTERS.contains(&r)) {
            continue;
        }
        if block_has(lines, t.line, "RELAXED:") {
            continue;
        }
        let who = match &recv {
            Some(r) => format!("`{r}`"),
            None => "this site".to_string(),
        };
        out.push(Finding {
            path: path.to_string(),
            line: t.line,
            rule: "relaxed-ordering-audit",
            msg: format!(
                "Ordering::Relaxed on {who} is neither an allowlisted counter nor justified \
                 by a `// RELAXED:` comment"
            ),
        });
    }
}

// ---------- rule: multi-lock-inventory ----------

const LOCK_METHODS: &[&str] = &["lock", "read", "write"];

/// Functions audited to legitimately hold several guards (documented in
/// docs/LINTS.md; extend deliberately, with a review).
const MULTI_LOCK_FNS: &[&str] = &["get_many"];

/// `(method_ident_idx, close_paren_idx)` for every `.lock()` / `.read()`
/// / `.write()` call in `toks[lo..hi]`.
fn lock_sites_in(toks: &[Token], lo: usize, hi: usize) -> Vec<(usize, usize)> {
    let mut sites = Vec::new();
    let mut i = lo;
    while i < hi {
        let t = &toks[i];
        if t.kind == Kind::Ident
            && LOCK_METHODS.contains(&t.text.as_str())
            && i >= 1
            && is_p(&toks[i - 1], '.')
            && i + 2 < toks.len()
            && is_p(&toks[i + 1], '(')
            && is_p(&toks[i + 2], ')')
        {
            sites.push((i, i + 2));
        }
        i += 1;
    }
    sites
}

/// From the `)` of a lock call, consume `.unwrap()` / `.expect(..)` / `?`;
/// index of the first token after the chain.
fn chain_tail(toks: &[Token], close_idx: usize) -> usize {
    let mut j = close_idx + 1;
    while j < toks.len() {
        if is_p(&toks[j], '?') {
            j += 1;
            continue;
        }
        if is_p(&toks[j], '.')
            && j + 2 < toks.len()
            && toks[j + 1].kind == Kind::Ident
            && matches!(toks[j + 1].text.as_str(), "unwrap" | "expect")
            && is_p(&toks[j + 2], '(')
        {
            j = match_fwd(toks, j + 2) + 1;
            continue;
        }
        break;
    }
    j
}

/// Walk back from the lock method ident to the start of its receiver
/// chain (`self.shards[si].read` starts at `self`).
fn chain_start(toks: &[Token], site_idx: usize) -> usize {
    let mut j = site_idx as i64 - 2; // skip the `.` before the method
    while j >= 0 {
        let t = &toks[j as usize];
        match t.kind {
            Kind::Ident | Kind::Lit => j -= 1,
            Kind::Punct => {
                let c = pch(t).unwrap_or(' ');
                if is_close(c) {
                    j = match_back(toks, j as usize) as i64 - 1;
                } else if matches!(c, '.' | '*' | '&' | ':') {
                    j -= 1;
                } else {
                    break;
                }
            }
            Kind::Lifetime => break,
        }
    }
    (j + 1) as usize
}

/// `(name, body_open_idx, body_close_idx)` for every `fn` with a body.
/// Nested fns are re-discovered when the scan resumes inside the body.
fn functions(toks: &[Token]) -> Vec<(String, usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if is_id(&toks[i], "fn") && i + 1 < toks.len() && toks[i + 1].kind == Kind::Ident {
            let name = toks[i + 1].text.clone();
            let mut j = i + 2;
            let mut pd = 0i64;
            let mut body = None;
            while j < toks.len() {
                if let Some(c) = pch(&toks[j]) {
                    match c {
                        '(' | '[' => pd += 1,
                        ')' | ']' => pd -= 1,
                        '{' if pd == 0 => {
                            body = Some(j);
                            break;
                        }
                        ';' if pd == 0 => break, // bodyless signature
                        _ => {}
                    }
                }
                j += 1;
            }
            if let Some(b) = body {
                let end = match_fwd(toks, b);
                out.push((name, b, end));
                i = b;
            } else {
                i = j;
            }
        }
        i += 1;
    }
    out
}

/// `depth[i - lo]` = brace depth of token `i` relative to the fn body.
fn brace_depths(toks: &[Token], lo: usize, hi: usize) -> Vec<i64> {
    let mut depth = Vec::with_capacity(hi - lo + 1);
    let mut d = 0i64;
    for t in toks.iter().take(hi + 1).skip(lo) {
        if is_p(t, '{') {
            d += 1;
        }
        depth.push(d);
        if is_p(t, '}') {
            d -= 1;
        }
    }
    depth
}

/// A lexically-detected live guard: `let g = x.lock().unwrap();` (or the
/// if/while-let form). `term` is the statement terminator token, `end`
/// the last token of the guard's scope.
struct Guard {
    let_idx: usize,
    line: usize,
    term: usize,
    end: usize,
    name: String,
}

/// Flag functions that (a) hold two lexically-live guards at once,
/// (b) take a lock while another guard is live, or (c) return a guard out
/// of a closure (guards can then accumulate across iterations). Audited
/// functions are allowlisted by name.
fn rule_multi_lock(path: &str, toks: &[Token], out: &mut Vec<Finding>) {
    for (name, lo, hi) in functions(toks) {
        let sites = lock_sites_in(toks, lo + 1, hi);
        if sites.is_empty() {
            continue;
        }
        if MULTI_LOCK_FNS.contains(&name.as_str()) {
            continue;
        }
        let depth = brace_depths(toks, lo, hi);
        let depth_at = |k: usize| -> i64 {
            if (lo..=hi).contains(&k) {
                depth[k - lo]
            } else {
                0
            }
        };
        let mut guards: Vec<Guard> = Vec::new();
        let mut i = lo + 1;
        while i < hi {
            if !is_id(&toks[i], "let") {
                i += 1;
                continue;
            }
            let iflet = i >= 1 && matches!(toks[i - 1].text.as_str(), "if" | "while");
            // Find the `=` introducing the initializer.
            let mut j = i + 1;
            let mut pd = 0i64;
            let mut eq = None;
            while j < hi {
                if let Some(c) = pch(&toks[j]) {
                    match c {
                        '(' | '[' | '{' | '<' => pd += 1,
                        ')' | ']' | '}' | '>' => pd -= 1,
                        '=' if pd == 0 && !matches!(toks.get(j + 1), Some(t) if is_p(t, '=')) => {
                            eq = Some(j);
                            break;
                        }
                        ';' => break,
                        _ => {}
                    }
                }
                j += 1;
            }
            let Some(eq) = eq else {
                i += 1;
                continue;
            };
            // Find the initializer's terminator: `;` for plain lets, the
            // body `{` for if/while-let.
            let mut j = eq + 1;
            let mut pd = 0i64;
            let mut term = None;
            while j <= hi {
                if let Some(c) = pch(&toks[j]) {
                    match c {
                        '(' | '[' => pd += 1,
                        ')' | ']' => pd -= 1,
                        ';' if pd == 0 && !iflet => {
                            term = Some(j);
                            break;
                        }
                        '{' if pd == 0 && iflet => {
                            term = Some(j);
                            break;
                        }
                        '{' if pd == 0 && !iflet => {
                            // Struct-literal / block initializer: skip it.
                            j = match_fwd(toks, j);
                        }
                        _ => {}
                    }
                }
                j += 1;
            }
            let Some(term) = term else {
                i += 1;
                continue;
            };
            for &(si, sc) in &lock_sites_in(toks, eq + 1, term) {
                // A guard binding must have no unmatched open paren before
                // the lock site: `mem::take(&mut *m.lock().unwrap())` is a
                // temporary inside a call, not a live guard.
                let mut unmatched = 0i64;
                for t in toks.iter().take(si).skip(eq + 1) {
                    match pch(t) {
                        Some('(') => unmatched += 1,
                        Some(')') => unmatched -= 1,
                        _ => {}
                    }
                }
                if unmatched != 0 {
                    continue;
                }
                if chain_tail(toks, sc) != term {
                    continue;
                }
                // Scope end: where brace depth drops below the `let`'s.
                let dlet = depth_at(i);
                let mut end = hi;
                let mut k = term;
                while k <= hi {
                    if depth_at(k) < dlet {
                        end = k;
                        break;
                    }
                    k += 1;
                }
                if iflet {
                    end = match_fwd(toks, term);
                }
                let gname = if toks[eq - 1].kind == Kind::Ident {
                    toks[eq - 1].text.clone()
                } else {
                    "_".to_string()
                };
                guards.push(Guard { let_idx: i, line: toks[i].line, term, end, name: gname });
                break;
            }
            i += 1;
        }
        let mut findings: std::collections::BTreeSet<(usize, String)> = Default::default();
        // (a) overlapping guards and (b) lock sites under a live guard.
        for (gi, g) in guards.iter().enumerate() {
            for h in &guards[gi + 1..] {
                if h.let_idx < g.end {
                    findings.insert((
                        h.line,
                        format!(
                            "fn `{}` holds lock guards `{}` (line {}) and `{}` at once",
                            name, g.name, g.line, h.name
                        ),
                    ));
                }
            }
            for &(si, _sc) in &sites {
                if g.term < si && si <= g.end {
                    findings.insert((
                        toks[si].line,
                        format!(
                            "fn `{}` takes another lock while guard `{}` (line {}) is held",
                            name, g.name, g.line
                        ),
                    ));
                }
            }
        }
        // (c) a closure whose body is just a lock chain returns the guard.
        for &(si, sc) in &sites {
            let after = chain_tail(toks, sc);
            if after < toks.len() && matches!(pch(&toks[after]), Some(')') | Some(',')) {
                let cs = chain_start(toks, si);
                if cs >= 1 && is_p(&toks[cs - 1], '|') {
                    findings.insert((
                        toks[si].line,
                        format!(
                            "fn `{name}`: closure returns a lock guard (guards may \
                             accumulate across iterations)"
                        ),
                    ));
                }
            }
        }
        for (line, msg) in findings {
            out.push(Finding { path: path.to_string(), line, rule: "multi-lock-inventory", msg });
        }
    }
}

// ---------- rule: replay-determinism ----------

/// Files on the WAL-replay path: recovery must be byte-identical, so no
/// wall clocks and no nondeterministic iteration order. The replication
/// subsystem ships and re-applies those same records (a follower is a
/// continuous replay), so all of `replication/` is held to the same bar.
/// The fault-injection layer is too: `gus loadgen --chaos <seed>` promises
/// the same seed replays the same faults bit-for-bit, which only holds if
/// plans, injectors, backoff jitter, and schedules stay clock-free.
/// (`fault/proxy.rs` is deliberately absent — it *executes* a schedule
/// against real sockets and necessarily reads the wall clock.)
const REPLAY_FILES: &[&str] = &[
    "coordinator/wal.rs",
    "coordinator/snapshot.rs",
    "protocol.rs",
    "admission/controller.rs",
    "replication/mod.rs",
    "replication/leader.rs",
    "replication/follower.rs",
    "replication/router.rs",
    "replication/health.rs",
    "fault/plan.rs",
    "fault/injector.rs",
    "fault/backoff.rs",
    "fault/schedule.rs",
];

const REPLAY_BANNED_CALLS: &[(&str, &str)] = &[("Instant", "now"), ("SystemTime", "now")];

const REPLAY_BANNED_TYPES: &[&str] = &["HashMap", "HashSet"];

fn rule_replay(path: &str, toks: &[Token], out: &mut Vec<Finding>) {
    let p = path.replace('\\', "/");
    if !REPLAY_FILES.iter().any(|s| p.ends_with(s)) {
        return;
    }
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident {
            continue;
        }
        for &(ty, meth) in REPLAY_BANNED_CALLS {
            if t.text == ty
                && i + 3 < toks.len()
                && is_p(&toks[i + 1], ':')
                && is_p(&toks[i + 2], ':')
                && is_id(&toks[i + 3], meth)
            {
                out.push(Finding {
                    path: path.to_string(),
                    line: t.line,
                    rule: "replay-determinism",
                    msg: format!(
                        "{ty}::{meth} in a replay-critical file (WAL replay must be \
                         deterministic)"
                    ),
                });
            }
        }
        if REPLAY_BANNED_TYPES.contains(&t.text.as_str()) {
            out.push(Finding {
                path: path.to_string(),
                line: t.line,
                rule: "replay-determinism",
                msg: format!(
                    "{} iteration order is nondeterministic; use BTreeMap/FxHashMap in \
                     replay-critical files",
                    t.text
                ),
            });
        }
    }
}

// ---------- rule: repr-c-size-assert ----------

/// Every `#[repr(C)]` type must have a compile-time size assertion
/// (`const _: () = assert!(size_of::<T>() == ..)`) somewhere in the file,
/// so layout drift fails the build instead of corrupting casts.
fn rule_repr_c(path: &str, toks: &[Token], out: &mut Vec<Finding>) {
    let mut i = 0usize;
    while i < toks.len() {
        if is_p(&toks[i], '#')
            && i + 2 < toks.len()
            && is_p(&toks[i + 1], '[')
            && is_id(&toks[i + 2], "repr")
        {
            let close = match_fwd(toks, i + 1);
            let is_c = toks[i + 3..close].iter().any(|t| is_id(t, "C"));
            let mut j = close + 1;
            // Skip further attributes and visibility to the item keyword.
            while j + 1 < toks.len() && is_p(&toks[j], '#') && is_p(&toks[j + 1], '[') {
                j = match_fwd(toks, j + 1) + 1;
            }
            if j < toks.len() && is_id(&toks[j], "pub") {
                j += 1;
                if j < toks.len() && is_p(&toks[j], '(') {
                    j = match_fwd(toks, j) + 1;
                }
            }
            if is_c
                && j + 1 < toks.len()
                && toks[j].kind == Kind::Ident
                && matches!(toks[j].text.as_str(), "struct" | "enum" | "union")
                && toks[j + 1].kind == Kind::Ident
            {
                let tname = toks[j + 1].text.clone();
                let mut ok = false;
                for k in 0..toks.len().saturating_sub(4) {
                    if is_id(&toks[k], "size_of") {
                        let mut m = k + 1;
                        if is_p(&toks[m], ':') && is_p(&toks[m + 1], ':') {
                            m += 2;
                        }
                        if is_p(&toks[m], '<') && is_id(&toks[m + 1], &tname) {
                            ok = true;
                            break;
                        }
                    }
                }
                if !ok {
                    out.push(Finding {
                        path: path.to_string(),
                        line: toks[i].line,
                        rule: "repr-c-size-assert",
                        msg: format!(
                            "#[repr(C)] type `{tname}` has no compile-time size assertion \
                             (const _: () = assert!(size_of::<{tname}>() == ..))"
                        ),
                    });
                }
            }
            i = j;
        }
        i += 1;
    }
}

/// Run every rule over one file's token stream.
pub fn run_all(path: &str, toks: &[Token], lines: &[LineInfo]) -> Vec<Finding> {
    let mut out = Vec::new();
    rule_float_sort(path, toks, &mut out);
    rule_unsafe(path, toks, lines, &mut out);
    rule_relaxed(path, toks, lines, &mut out);
    rule_multi_lock(path, toks, &mut out);
    rule_replay(path, toks, &mut out);
    rule_repr_c(path, toks, &mut out);
    out
}
