//! A minimal hand-rolled Rust lexer: just enough token structure for the
//! lint rules, with no dependencies.
//!
//! Produces a flat token stream (identifiers, single-char punctuation,
//! literals, lifetimes) plus per-line comment metadata used to attach
//! `// SAFETY:` / `// RELAXED:` / `// lint:allow(..)` comments to code.
//! Handles line and nested block comments, regular/raw/byte strings,
//! char-vs-lifetime disambiguation, and numeric literals with exponents.
//! It is deliberately not a full lexer: anything exotic degrades to
//! punctuation tokens, which is sound for every rule built on top.

/// Token class. Punctuation is always a single character (`::` is two
/// `:` tokens, `..` two `.` tokens); rules match multi-char operators
/// positionally.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    Lit,
    Lifetime,
}

#[derive(Debug, Clone)]
pub struct Token {
    pub kind: Kind,
    pub text: String,
    /// 1-based source line of the token's first character.
    pub line: usize,
}

/// Per-line metadata: concatenated comment text (line + block comments
/// starting on that line) and whether any non-comment token starts there.
#[derive(Debug, Clone, Default)]
pub struct LineInfo {
    pub comment: String,
    pub has_code: bool,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// `r"..."` / `r#"..."#` / `b"..."` / `br#"..."#` prefix at `i`
/// (where `chars[i]` is `r` or `b`): returns (quote index, hash count,
/// is_raw), or None when this is just an identifier starting with r/b.
fn string_prefix(chars: &[char], i: usize) -> Option<(usize, usize, bool)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    let raw = chars.get(j) == Some(&'r');
    if raw {
        j += 1;
    }
    if j == i {
        return None;
    }
    let h0 = j;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    let hashes = j - h0;
    if chars.get(j) == Some(&'"') && (raw || hashes == 0) {
        Some((j, hashes, raw))
    } else {
        None
    }
}

/// Scan past a non-raw string body starting after the opening quote at
/// `start`; returns the index one past the closing quote.
fn scan_escaped_string(chars: &[char], start: usize) -> usize {
    let n = chars.len();
    let mut k = start + 1;
    while k < n && chars[k] != '"' {
        if chars[k] == '\\' {
            k += 2;
        } else {
            k += 1;
        }
    }
    (k + 1).min(n)
}

/// Lex `src` into a token stream plus per-line comment info. `lines` is
/// indexed by 1-based line number and sized to cover the whole file.
pub fn lex(src: &str) -> (Vec<Token>, Vec<LineInfo>) {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let nlines = src.matches('\n').count() + 2;
    let mut lines = vec![LineInfo::default(); nlines + 1];
    let mut toks: Vec<Token> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also covers /// and //! doc comments).
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            let mut j = i;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            let text: String = chars[i..j].iter().collect();
            lines[line].comment.push_str(&text);
            lines[line].comment.push(' ');
            i = j;
            continue;
        }
        // Block comment, possibly nested; text accrues to each line it spans.
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '/' && chars.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && chars.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                } else if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else {
                    lines[line].comment.push(chars[j]);
                    j += 1;
                }
            }
            lines[line].comment.push(' ');
            i = j;
            continue;
        }
        // Raw / byte strings: r".." r#".."# b".." br".." etc.
        if c == 'r' || c == 'b' {
            if let Some((quote, hashes, raw)) = string_prefix(&chars, i) {
                let end = if raw {
                    // Find `"` followed by `hashes` `#`s.
                    let mut k = quote + 1;
                    loop {
                        if k >= n {
                            break n;
                        }
                        if chars[k] == '"' {
                            let mut h = 0usize;
                            while h < hashes && chars.get(k + 1 + h) == Some(&'#') {
                                h += 1;
                            }
                            if h == hashes {
                                break k + 1 + hashes;
                            }
                        }
                        k += 1;
                    }
                } else {
                    scan_escaped_string(&chars, quote)
                };
                let text: String = chars[i..end].iter().collect();
                let newlines = text.matches('\n').count();
                toks.push(Token { kind: Kind::Lit, text, line });
                lines[line].has_code = true;
                line += newlines;
                i = end;
                continue;
            }
        }
        // Plain string.
        if c == '"' {
            let end = scan_escaped_string(&chars, i);
            let text: String = chars[i..end].iter().collect();
            let newlines = text.matches('\n').count();
            toks.push(Token { kind: Kind::Lit, text, line });
            lines[line].has_code = true;
            line += newlines;
            i = end;
            continue;
        }
        // Char literal or lifetime.
        if c == '\'' {
            if chars.get(i + 1).copied().is_some_and(is_ident_start) {
                let mut j = i + 1;
                while j < n && is_ident_continue(chars[j]) {
                    j += 1;
                }
                if chars.get(j) == Some(&'\'') {
                    // 'a' — a char literal.
                    let text: String = chars[i..=j].iter().collect();
                    toks.push(Token { kind: Kind::Lit, text, line });
                    lines[line].has_code = true;
                    i = j + 1;
                } else {
                    // 'a / 'static — a lifetime.
                    let text: String = chars[i..j].iter().collect();
                    toks.push(Token { kind: Kind::Lifetime, text, line });
                    lines[line].has_code = true;
                    i = j;
                }
                continue;
            }
            // '\n', '\'', 'x', or similar.
            let mut j = i + 1;
            if chars.get(j) == Some(&'\\') {
                j += 2;
                while j < n && chars[j] != '\'' {
                    j += 1;
                }
            } else {
                j += 1;
            }
            j += 1;
            let end = j.min(n);
            let text: String = chars[i..end].iter().collect();
            toks.push(Token { kind: Kind::Lit, text, line });
            lines[line].has_code = true;
            i = end;
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_continue(chars[j]) {
                j += 1;
            }
            let text: String = chars[i..j].iter().collect();
            toks.push(Token { kind: Kind::Ident, text, line });
            lines[line].has_code = true;
            i = j;
            continue;
        }
        // Numeric literal (suffixes, exponents, and `1.5` but not `1.`).
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n {
                let ch = chars[j];
                if is_ident_continue(ch) {
                    j += 1;
                } else if ch == '.' && chars.get(j + 1).is_some_and(|d| d.is_ascii_digit()) {
                    j += 1;
                } else if (ch == '+' || ch == '-')
                    && j > i
                    && matches!(chars[j - 1], 'e' | 'E')
                {
                    j += 1;
                } else {
                    break;
                }
            }
            let text: String = chars[i..j].iter().collect();
            toks.push(Token { kind: Kind::Lit, text, line });
            lines[line].has_code = true;
            i = j;
            continue;
        }
        toks.push(Token { kind: Kind::Punct, text: c.to_string(), line });
        lines[line].has_code = true;
        i += 1;
    }
    (toks, lines)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).0.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_numbers() {
        let ts = kinds("let x = 1.5e-3f32;");
        assert_eq!(
            ts,
            vec![
                (Kind::Ident, "let".into()),
                (Kind::Ident, "x".into()),
                (Kind::Punct, "=".into()),
                (Kind::Lit, "1.5e-3f32".into()),
                (Kind::Punct, ";".into()),
            ]
        );
    }

    #[test]
    fn comments_attach_to_lines() {
        let (toks, lines) = lex("// SAFETY: fine\nunsafe { x() }\n");
        assert!(lines[1].comment.contains("SAFETY:"));
        assert!(!lines[1].has_code);
        assert!(lines[2].has_code);
        assert_eq!(toks[0].text, "unsafe");
        assert_eq!(toks[0].line, 2);
    }

    #[test]
    fn nested_block_comment() {
        let (toks, lines) = lex("/* a /* b */ c */ fn f() {}\n");
        assert_eq!(toks[0].text, "fn");
        assert!(lines[1].comment.contains('a'));
        assert!(lines[1].comment.contains('c'));
    }

    #[test]
    fn strings_are_single_tokens() {
        let ts = kinds(r#"let s = "a // not a comment";"#);
        assert_eq!(ts[3].0, Kind::Lit);
        assert!(ts[3].1.contains("not a comment"));
        let ts = kinds("let s = r#\"raw \\ body\"#;");
        assert_eq!(ts[3].0, Kind::Lit);
        assert!(ts[3].1.contains("raw"));
        let ts = kinds(r#"let b = b"bytes";"#);
        assert_eq!(ts[3].0, Kind::Lit);
    }

    #[test]
    fn char_vs_lifetime() {
        let ts = kinds("fn f<'a>(x: &'a u8) { let c = 'x'; let nl = '\\n'; }");
        assert!(ts.iter().any(|t| t.0 == Kind::Lifetime && t.1 == "'a"));
        assert!(ts.iter().any(|t| t.0 == Kind::Lit && t.1 == "'x'"));
        assert!(ts.iter().any(|t| t.0 == Kind::Lit && t.1 == "'\\n'"));
    }

    #[test]
    fn multiline_string_advances_lines() {
        let (toks, _) = lex("let s = \"a\nb\";\nfn f() {}\n");
        let f = toks.iter().find(|t| t.text == "fn").unwrap();
        assert_eq!(f.line, 3);
    }
}
