//! gus-lint: repo-native static analysis for `dynamic_gus`.
//!
//! Six rules, each born from a bug class this repo has shipped or
//! audited (docs/LINTS.md has the full history):
//!
//! - `float-sort-safety` — no `partial_cmp(..).unwrap()` and no
//!   `partial_cmp` comparators in sorts; NaN panics a serving thread.
//! - `undocumented-unsafe` — every `unsafe` carries a `// SAFETY:`
//!   comment.
//! - `relaxed-ordering-audit` — `Ordering::Relaxed` only on allowlisted
//!   counters or with a `// RELAXED:` justification.
//! - `multi-lock-inventory` — functions lexically holding ≥2 lock guards
//!   are flagged unless allowlisted as audited.
//! - `replay-determinism` — no wall clocks or hash-order iteration in
//!   WAL-replay-critical files.
//! - `repr-c-size-assert` — every `#[repr(C)]` type has a compile-time
//!   size assertion.
//!
//! Suppress a finding with `// lint:allow(rule-id)` (or
//! `lint:allow(all)`) on the offending line or the comment block above.
//!
//! std-only by design: the lexer is hand-rolled (no `syn`, no
//! proc-macro), matching the repo's vendored-deps discipline.

#![forbid(unsafe_code)]

pub mod lexer;
pub mod rules;

pub use rules::Finding;

use std::path::{Path, PathBuf};

/// Directories never linted: build output, lint fixtures (deliberately
/// dirty), and vendored stubs (not this repo's code).
pub const SKIP_DIRS: &[&str] = &["target", "fixtures", "vendor"];

/// All rule IDs, for `--help` and the self-tests.
pub const RULE_IDS: &[&str] = &[
    "float-sort-safety",
    "undocumented-unsafe",
    "relaxed-ordering-audit",
    "multi-lock-inventory",
    "replay-determinism",
    "repr-c-size-assert",
];

/// Lint one file's source text. `path` is used for diagnostics and for
/// the path-scoped replay-determinism rule.
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let (toks, lines) = lexer::lex(src);
    rules::run_all(path, &toks, &lines)
        .into_iter()
        .filter(|f| !rules::suppressed(&lines, f.line, f.rule))
        .collect()
}

/// Collect `.rs` files under `p` (or `p` itself when it is a file),
/// skipping [`SKIP_DIRS`], in sorted order.
pub fn collect_rs_files(p: &Path) -> Vec<PathBuf> {
    let mut acc = Vec::new();
    if p.is_file() {
        if p.extension().is_some_and(|e| e == "rs") {
            acc.push(p.to_path_buf());
        }
        return acc;
    }
    let mut stack = vec![p.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let Ok(rd) = std::fs::read_dir(&dir) else {
            continue;
        };
        let mut entries: Vec<PathBuf> = rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
        entries.sort();
        for e in entries {
            if e.is_dir() {
                let skip = e
                    .file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| SKIP_DIRS.contains(&n));
                if !skip {
                    stack.push(e);
                }
            } else if e.extension().is_some_and(|x| x == "rs") {
                acc.push(e);
            }
        }
    }
    acc.sort();
    acc
}

/// Lint every `.rs` file under the given paths. Returns the sorted
/// findings and the number of files examined. Unreadable files are
/// reported as an `io-error` finding rather than silently skipped.
pub fn lint_paths(paths: &[PathBuf]) -> (Vec<Finding>, usize) {
    let mut files = Vec::new();
    for p in paths {
        files.extend(collect_rs_files(p));
    }
    let mut findings = Vec::new();
    for f in &files {
        let shown = f.display().to_string();
        match std::fs::read_to_string(f) {
            Ok(src) => findings.extend(lint_source(&shown, &src)),
            Err(e) => findings.push(Finding {
                path: shown,
                line: 0,
                rule: "io-error",
                msg: format!("cannot read file: {e}"),
            }),
        }
    }
    findings.sort_by(|a, b| {
        (&a.path, a.line, a.rule, &a.msg).cmp(&(&b.path, b.line, b.rule, &b.msg))
    });
    (findings, files.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suppression_comment_is_honored() {
        let bad = "fn f(v: &mut Vec<f32>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        assert_eq!(lint_source("x.rs", bad).len(), 1);
        let ok = format!("// lint:allow(float-sort-safety)\n{bad}");
        assert!(lint_source("x.rs", &ok).is_empty());
        let all = format!("// lint:allow(all)\n{bad}");
        assert!(lint_source("x.rs", &all).is_empty());
        // Suppressing a different rule does not hide the finding.
        let other = format!("// lint:allow(undocumented-unsafe)\n{bad}");
        assert_eq!(lint_source("x.rs", &other).len(), 1);
    }

    #[test]
    fn findings_carry_path_line_rule() {
        let bad = "fn f() {\n    let x = a.partial_cmp(&b).unwrap();\n}\n";
        let fs = lint_source("src/foo.rs", bad);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].path, "src/foo.rs");
        assert_eq!(fs[0].line, 2);
        assert_eq!(fs[0].rule, "float-sort-safety");
    }
}
