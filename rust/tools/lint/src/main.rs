//! CLI: `gus-lint PATH...` lints every `.rs` file under the given paths
//! and exits non-zero when there are findings.
//!
//! From `rust/`: `cargo run -q -p gus-lint -- src tests benches`

use std::path::PathBuf;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args.iter().any(|a| a == "--help" || a == "-h") {
        eprintln!("usage: gus-lint PATH...");
        eprintln!();
        eprintln!("Lints .rs files under each PATH (skipping {:?}).", gus_lint::SKIP_DIRS);
        eprintln!("Rules: {}", gus_lint::RULE_IDS.join(", "));
        eprintln!("Suppress one finding with `// lint:allow(rule-id)` on or above the line.");
        std::process::exit(if args.is_empty() { 2 } else { 0 });
    }
    let paths: Vec<PathBuf> = args.iter().map(PathBuf::from).collect();
    let (findings, n_files) = gus_lint::lint_paths(&paths);
    for f in &findings {
        println!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.msg);
    }
    eprintln!("{} finding(s) in {} file(s)", findings.len(), n_files);
    std::process::exit(if findings.is_empty() { 0 } else { 1 });
}
